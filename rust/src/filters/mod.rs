//! Baseline artifact-mitigation filters from the paper's evaluation
//! (§VIII-A): Gaussian, uniform (mean), and Wiener, each over a
//! 3×3(×3) window like the paper. These are classical image-restoration
//! smoothers; Table II shows they do *not* guarantee the relaxed error
//! bound, unlike the quantization-aware compensation.
//!
//! Boundary handling is `reflect` (mirror) on every axis, the
//! scipy.ndimage default, so the Python tests can cross-check numerics.

pub mod gaussian;
pub mod uniform;
pub mod wiener;

pub use gaussian::gaussian_filter;
pub use uniform::uniform_filter;
pub use wiener::wiener_filter;

use crate::data::grid::{Grid, Shape};

/// Reflected (mirror) index for out-of-range positions, scipy `reflect`
/// convention: `(d c b a | a b c d | d c b a)`.
#[inline]
pub(crate) fn reflect(pos: isize, n: usize) -> usize {
    debug_assert!(n > 0);
    if n == 1 {
        return 0;
    }
    let period = 2 * n as isize;
    let mut p = pos % period;
    if p < 0 {
        p += period;
    }
    let p = p as usize;
    if p < n {
        p
    } else {
        2 * n - 1 - p
    }
}

/// Apply a symmetric odd-length 1D kernel separably along every active
/// axis (unit axes skipped). `kernel.len()` must be odd.
pub(crate) fn separable_filter(grid: &Grid<f32>, kernel: &[f64]) -> Grid<f32> {
    assert!(kernel.len() % 2 == 1, "kernel must be odd-length");
    let shape = grid.shape;
    let mut cur: Vec<f64> = grid.data.iter().map(|&v| v as f64).collect();
    for axis in shape.active_axes().collect::<Vec<_>>() {
        cur = convolve_axis(&cur, shape, axis, kernel);
    }
    let mut out = Grid::from_vec(cur.iter().map(|&v| v as f32).collect(), shape.user_dims());
    out.shape.ndim = shape.ndim;
    out
}

/// 1D convolution along `axis` with reflect boundaries.
pub(crate) fn convolve_axis(data: &[f64], shape: Shape, axis: usize, kernel: &[f64]) -> Vec<f64> {
    let dims = shape.dims;
    let stride = shape.strides()[axis];
    let n = dims[axis];
    let radius = kernel.len() / 2;
    let (oa, ob) = match axis {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };
    let mut out = vec![0.0f64; data.len()];
    let mut line = vec![0.0f64; n];
    for a in 0..dims[oa] {
        for b in 0..dims[ob] {
            let base = match axis {
                0 => shape.idx(0, a, b),
                1 => shape.idx(a, 0, b),
                _ => shape.idx(a, b, 0),
            };
            for (t, dst) in line.iter_mut().enumerate() {
                *dst = data[base + t * stride];
            }
            for p in 0..n {
                let mut acc = 0.0;
                for (t, &w) in kernel.iter().enumerate() {
                    let q = reflect(p as isize + t as isize - radius as isize, n);
                    acc += w * line[q];
                }
                out[base + p * stride] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reflect_matches_scipy_convention() {
        // n = 4: positions -2,-1,0,1,2,3,4,5 → 1,0,0,1,2,3,3,2
        let got: Vec<usize> = (-2..6).map(|p| reflect(p, 4)).collect();
        assert_eq!(got, vec![1, 0, 0, 1, 2, 3, 3, 2]);
    }

    #[test]
    fn reflect_n1_always_zero() {
        for p in -3..4 {
            assert_eq!(reflect(p, 1), 0);
        }
    }

    #[test]
    fn identity_kernel_is_noop() {
        let g = Grid::from_vec((0..24).map(|x| x as f32).collect(), &[4, 6]);
        let out = separable_filter(&g, &[0.0, 1.0, 0.0]);
        assert_eq!(out.data, g.data);
    }

    #[test]
    fn mean_kernel_preserves_constant() {
        let g = Grid::from_vec(vec![5.0f32; 27], &[3, 3, 3]);
        let k = [1.0 / 3.0; 3];
        let out = separable_filter(&g, &k);
        for v in out.data {
            assert!((v - 5.0).abs() < 1e-6);
        }
    }
}
