//! Baseline artifact-mitigation filters from the paper's evaluation
//! (§VIII-A): Gaussian, uniform (mean), and Wiener, each over a
//! 3×3(×3) window like the paper. These are classical image-restoration
//! smoothers; Table II shows they do *not* guarantee the relaxed error
//! bound, unlike the quantization-aware compensation.
//!
//! Boundary handling is `reflect` (mirror) on every axis, the
//! scipy.ndimage default, so the Python tests can cross-check numerics.
//!
//! The plain `*_filter` entry points run sequentially — they are the
//! baselines the quality tables time against `threads = 1` mitigation,
//! so their execution model matches the seed exactly. The `*_threads`
//! variants fan the independent convolution lines out on the shared
//! [`crate::util::pool`] with bit-identical output.

pub mod gaussian;
pub mod uniform;
pub mod wiener;

pub use gaussian::{gaussian_filter, gaussian_filter_on, gaussian_filter_threads};
pub use uniform::{uniform_filter, uniform_filter_sized_on, uniform_filter_threads};
pub use wiener::{wiener_filter, wiener_filter_sized_on, wiener_filter_threads};

use crate::data::grid::{Grid, Shape};
use crate::util::pool::{PoolHandle, UnsafeSlice};

/// Reflected (mirror) index for out-of-range positions, scipy `reflect`
/// convention: `(d c b a | a b c d | d c b a)`.
#[inline]
pub(crate) fn reflect(pos: isize, n: usize) -> usize {
    debug_assert!(n > 0);
    if n == 1 {
        return 0;
    }
    let period = 2 * n as isize;
    let mut p = pos % period;
    if p < 0 {
        p += period;
    }
    let p = p as usize;
    if p < n {
        p
    } else {
        2 * n - 1 - p
    }
}

/// Apply a symmetric odd-length 1D kernel separably along every active
/// axis (unit axes skipped). `kernel.len()` must be odd. `threads = 1`
/// is the sequential baseline path (bit-identical to the pool path);
/// parallel regions are confined to `pool`.
pub(crate) fn separable_filter(
    grid: &Grid<f32>,
    kernel: &[f64],
    threads: usize,
    pool: PoolHandle<'_>,
) -> Grid<f32> {
    assert!(kernel.len() % 2 == 1, "kernel must be odd-length");
    let shape = grid.shape;
    let mut cur: Vec<f64> = grid.data.iter().map(|&v| v as f64).collect();
    for axis in shape.active_axes().collect::<Vec<_>>() {
        cur = convolve_axis(&cur, shape, axis, kernel, threads, pool);
    }
    let mut out = Grid::from_vec(cur.iter().map(|&v| v as f32).collect(), shape.user_dims());
    out.shape.ndim = shape.ndim;
    out
}

/// 1D convolution along `axis` with reflect boundaries.
///
/// Lines perpendicular to `axis` are independent, so with `threads > 1`
/// they run on the selected `pool` (batched, with one per-batch line
/// buffer); `threads = 1` stays a pool-free sequential loop. Each
/// output value is computed by the same per-line expression regardless
/// of schedule, so the result is bit-identical across thread counts.
pub(crate) fn convolve_axis(
    data: &[f64],
    shape: Shape,
    axis: usize,
    kernel: &[f64],
    threads: usize,
    pool: PoolHandle<'_>,
) -> Vec<f64> {
    let dims = shape.dims;
    let stride = shape.strides()[axis];
    let n = dims[axis];
    let radius = kernel.len() / 2;
    let (oa, ob) = match axis {
        0 => (1, 2),
        1 => (0, 2),
        _ => (0, 1),
    };
    let n_lines = dims[oa] * dims[ob];
    let mut out = vec![0.0f64; data.len()];
    let o = UnsafeSlice::new(&mut out);
    pool.for_batches(n_lines, threads, 8, |lines| {
        let mut line = vec![0.0f64; n];
        let mut out_line = vec![0.0f64; n];
        // Per-position reference expression; identical tap order to the
        // vectorized interior, so the split below changes no bits.
        let conv_at = |line: &[f64], p: usize| {
            let mut acc = 0.0;
            for (t, &w) in kernel.iter().enumerate() {
                let q = reflect(p as isize + t as isize - radius as isize, n);
                acc += w * line[q];
            }
            acc
        };
        for lid in lines {
            let a = lid / dims[ob];
            let b = lid % dims[ob];
            let base = match axis {
                0 => shape.idx(0, a, b),
                1 => shape.idx(a, 0, b),
                _ => shape.idx(a, b, 0),
            };
            for (t, dst) in line.iter_mut().enumerate() {
                *dst = data[base + t * stride];
            }
            if n > 2 * radius {
                // Reflection only touches the first/last `radius`
                // positions; the interior is a boundary-free valid
                // convolution and runs on the SIMD substrate.
                for (p, dst) in out_line.iter_mut().enumerate().take(radius) {
                    *dst = conv_at(&line, p);
                }
                for (p, dst) in out_line.iter_mut().enumerate().skip(n - radius) {
                    *dst = conv_at(&line, p);
                }
                crate::util::simd::convolve_valid(
                    &mut out_line[radius..n - radius],
                    &line,
                    kernel,
                );
            } else {
                for (p, dst) in out_line.iter_mut().enumerate() {
                    *dst = conv_at(&line, p);
                }
            }
            for (p, &v) in out_line.iter().enumerate() {
                // SAFETY: each line id owns a disjoint set of `out`
                // indices (distinct bases, same in-line offsets).
                unsafe { o.write(base + p * stride, v) };
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reflect_matches_scipy_convention() {
        // n = 4: positions -2,-1,0,1,2,3,4,5 → 1,0,0,1,2,3,3,2
        let got: Vec<usize> = (-2..6).map(|p| reflect(p, 4)).collect();
        assert_eq!(got, vec![1, 0, 0, 1, 2, 3, 3, 2]);
    }

    #[test]
    fn reflect_n1_always_zero() {
        for p in -3..4 {
            assert_eq!(reflect(p, 1), 0);
        }
    }

    #[test]
    fn identity_kernel_is_noop() {
        let g = Grid::from_vec((0..24).map(|x| x as f32).collect(), &[4, 6]);
        let out = separable_filter(&g, &[0.0, 1.0, 0.0], 1, PoolHandle::Global);
        assert_eq!(out.data, g.data);
    }

    #[test]
    fn mean_kernel_preserves_constant() {
        let g = Grid::from_vec(vec![5.0f32; 27], &[3, 3, 3]);
        let k = [1.0 / 3.0; 3];
        let out = separable_filter(&g, &k, 1, PoolHandle::Global);
        for v in out.data {
            assert!((v - 5.0).abs() < 1e-6);
        }
    }

    #[test]
    fn threaded_filters_match_sequential_bitwise() {
        let g = Grid::from_vec((0..17 * 13).map(|x| (x as f32 * 0.37).sin()).collect(), &[17, 13]);
        let k = crate::filters::gaussian::gaussian_kernel(1.0, 1);
        let seq = separable_filter(&g, &k, 1, PoolHandle::Global);
        for threads in [2usize, 4, 16] {
            let par = separable_filter(&g, &k, threads, PoolHandle::Global);
            assert_eq!(par.data, seq.data, "threads={threads}");
        }
        let seq = wiener_filter(&g, 0.05);
        let par = wiener_filter_threads(&g, 0.05, 4);
        assert_eq!(par.data, seq.data);
        assert_eq!(gaussian_filter_threads(&g, 1.0, 4).data, gaussian_filter(&g, 1.0).data);
        assert_eq!(uniform_filter_threads(&g, 4).data, uniform_filter(&g).data);
    }
}
