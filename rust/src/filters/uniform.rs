//! Uniform (box/mean) filter over a 3×3(×3) window, the Table II /
//! Fig. 5–6 "Uniform" baseline.

use crate::data::grid::Grid;
use crate::filters::separable_filter;
use crate::util::pool::PoolHandle;

/// Separable mean filter with window extent `size` (odd) per active axis.
/// Sequential (the quality-baseline execution model).
pub fn uniform_filter_sized(grid: &Grid<f32>, size: usize) -> Grid<f32> {
    uniform_filter_sized_threads(grid, size, 1)
}

/// [`uniform_filter_sized`] with its convolution lines on the shared
/// pool; output is bit-identical to the sequential path.
pub fn uniform_filter_sized_threads(grid: &Grid<f32>, size: usize, threads: usize) -> Grid<f32> {
    uniform_filter_sized_on(PoolHandle::Global, grid, size, threads)
}

/// [`uniform_filter_sized_threads`] with its parallel regions confined
/// to `pool`.
pub fn uniform_filter_sized_on(
    pool: PoolHandle<'_>,
    grid: &Grid<f32>,
    size: usize,
    threads: usize,
) -> Grid<f32> {
    assert!(size % 2 == 1 && size >= 1);
    let k = vec![1.0 / size as f64; size];
    separable_filter(grid, &k, threads, pool)
}

/// The paper's 3-wide uniform filter. Sequential.
pub fn uniform_filter(grid: &Grid<f32>) -> Grid<f32> {
    uniform_filter_sized(grid, 3)
}

/// [`uniform_filter`] with its convolution lines on the shared pool;
/// output is bit-identical to the sequential path.
pub fn uniform_filter_threads(grid: &Grid<f32>, threads: usize) -> Grid<f32> {
    uniform_filter_sized_threads(grid, 3, threads)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interior_value_is_neighborhood_mean_2d() {
        let g = Grid::from_vec((0..25).map(|x| x as f32).collect(), &[5, 5]);
        let f = uniform_filter(&g);
        // center (2,2): mean of the 3x3 block around it = value at center
        // for a linear ramp
        assert!((f.at(0, 2, 2) - g.at(0, 2, 2)).abs() < 1e-5);
        // hand-computed corner with reflect: block indices mirror
        let manual: f32 = {
            let idx = |i: isize, j: isize| {
                let r = |p: isize| crate::filters::reflect(p, 5);
                g.at(0, r(i), r(j))
            };
            let mut s = 0.0;
            for di in -1..=1 {
                for dj in -1..=1 {
                    s += idx(di, dj);
                }
            }
            s / 9.0
        };
        assert!((f.at(0, 0, 0) - manual).abs() < 1e-5);
    }

    #[test]
    fn constant_is_fixed_point() {
        let g = Grid::from_vec(vec![2.5f32; 3 * 4 * 5], &[3, 4, 5]);
        let f = uniform_filter(&g);
        for v in f.data {
            assert!((v - 2.5).abs() < 1e-6);
        }
    }

    #[test]
    fn size_one_is_identity() {
        let g = Grid::from_vec((0..12).map(|x| (x as f32).cos()).collect(), &[3, 4]);
        assert_eq!(uniform_filter_sized(&g, 1).data, g.data);
    }
}
