//! Adaptive Wiener filter over a 3×3(×3) window, the strongest classical
//! baseline in Table II.
//!
//! Pixel-wise: with local mean `μ` and local variance `σ²` over the
//! window, and noise power `ν`,
//!
//! ```text
//! out = μ + max(0, σ² − ν) / max(σ², ν) · (x − μ)
//! ```
//!
//! Following the paper, the noise power defaults to `ε²/3` — the variance
//! of a uniform error on `[−ε, ε]` — because the true error variance is
//! unknown post-decompression.

use crate::data::grid::Grid;
use crate::filters::convolve_axis;
use crate::util::pool::PoolHandle;

/// Noise-power estimate the paper uses for quantization noise at
/// absolute bound `eps_abs`.
pub fn quantization_noise_power(eps_abs: f64) -> f64 {
    eps_abs * eps_abs / 3.0
}

/// Wiener-filter `grid` with window extent `size` (odd) and noise power
/// `noise`. Sequential (the quality-baseline execution model).
pub fn wiener_filter_sized(grid: &Grid<f32>, size: usize, noise: f64) -> Grid<f32> {
    wiener_filter_sized_threads(grid, size, noise, 1)
}

/// [`wiener_filter_sized`] with its convolution lines on the shared
/// pool; output is bit-identical to the sequential path.
pub fn wiener_filter_sized_threads(
    grid: &Grid<f32>,
    size: usize,
    noise: f64,
    threads: usize,
) -> Grid<f32> {
    wiener_filter_sized_on(PoolHandle::Global, grid, size, noise, threads)
}

/// [`wiener_filter_sized_threads`] with its parallel regions confined
/// to `pool`.
pub fn wiener_filter_sized_on(
    pool: PoolHandle<'_>,
    grid: &Grid<f32>,
    size: usize,
    noise: f64,
    threads: usize,
) -> Grid<f32> {
    assert!(size % 2 == 1 && size >= 1);
    assert!(noise >= 0.0);
    let shape = grid.shape;
    let mean_k = vec![1.0 / size as f64; size];

    // Local mean and local second moment via separable box means.
    let x: Vec<f64> = grid.data.iter().map(|&v| v as f64).collect();
    let xx: Vec<f64> = x.iter().map(|&v| v * v).collect();
    let mut mean = x.clone();
    let mut m2 = xx;
    for axis in shape.active_axes().collect::<Vec<_>>() {
        mean = convolve_axis(&mean, shape, axis, &mean_k, threads, pool);
        m2 = convolve_axis(&m2, shape, axis, &mean_k, threads, pool);
    }

    let out: Vec<f32> = x
        .iter()
        .zip(mean.iter().zip(&m2))
        .map(|(&xi, (&mu, &s2))| {
            let var = (s2 - mu * mu).max(0.0);
            let gain = (var - noise).max(0.0) / var.max(noise).max(f64::MIN_POSITIVE);
            (mu + gain * (xi - mu)) as f32
        })
        .collect();
    let mut g = Grid::from_vec(out, shape.user_dims());
    g.shape.ndim = shape.ndim;
    g
}

/// The paper's 3-wide Wiener filter with ε²/3 noise power. Sequential.
pub fn wiener_filter(grid: &Grid<f32>, eps_abs: f64) -> Grid<f32> {
    wiener_filter_sized(grid, 3, quantization_noise_power(eps_abs))
}

/// [`wiener_filter`] with its convolution lines on the shared pool;
/// output is bit-identical to the sequential path.
pub fn wiener_filter_threads(grid: &Grid<f32>, eps_abs: f64, threads: usize) -> Grid<f32> {
    wiener_filter_sized_threads(grid, 3, quantization_noise_power(eps_abs), threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn noise_power_formula() {
        assert!((quantization_noise_power(0.3) - 0.03).abs() < 1e-12);
    }

    #[test]
    fn flat_region_collapses_to_mean() {
        // Variance ≪ noise → gain 0 → output = local mean.
        let mut rng = Rng::new(2);
        let data: Vec<f32> = (0..64).map(|_| 5.0 + 1e-4 * (rng.f32() - 0.5)).collect();
        let g = Grid::from_vec(data, &[8, 8]);
        let f = wiener_filter_sized(&g, 3, 1.0);
        for v in &f.data {
            assert!((v - 5.0).abs() < 1e-3);
        }
    }

    #[test]
    fn high_contrast_edges_preserved() {
        // Variance ≫ noise → gain ≈ 1 → output ≈ input.
        let mut data = vec![0.0f32; 64];
        for (i, v) in data.iter_mut().enumerate() {
            *v = if i % 8 >= 4 { 100.0 } else { -100.0 };
        }
        let g = Grid::from_vec(data.clone(), &[8, 8]);
        let f = wiener_filter_sized(&g, 3, 1e-6);
        let max_dev = g
            .data
            .iter()
            .zip(&f.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_dev < 1.0, "max_dev={max_dev}");
    }

    #[test]
    fn zero_noise_is_near_identity() {
        let mut rng = Rng::new(3);
        let data: Vec<f32> = (0..125).map(|_| rng.f32()).collect();
        let g = Grid::from_vec(data, &[5, 5, 5]);
        let f = wiener_filter_sized(&g, 3, 0.0);
        for (a, b) in g.data.iter().zip(&f.data) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn reduces_quantization_noise_on_smooth_field() {
        // Smooth ramp + uniform quantization-like noise: Wiener should cut MSE.
        let mut rng = Rng::new(4);
        let n = 32;
        let orig: Vec<f32> =
            (0..n * n).map(|i| ((i / n) as f32 * 0.1) + ((i % n) as f32 * 0.07)).collect();
        let eps = 0.3f64;
        let noisy: Vec<f32> =
            orig.iter().map(|&v| v + (2.0 * rng.f32() - 1.0) * eps as f32).collect();
        let go = Grid::from_vec(orig, &[n, n]);
        let gn = Grid::from_vec(noisy, &[n, n]);
        let gf = wiener_filter(&gn, eps);
        let mse = |a: &[f32], b: &[f32]| {
            a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
        };
        assert!(mse(&go.data, &gf.data) < mse(&go.data, &gn.data));
    }
}
