//! Work-stealing scheduler integration tests: nested-region
//! bit-exactness across thread counts, the tasks-outnumber-workers
//! deadlock reproducer (which cooperative helping must now complete),
//! steal-counter proof on an imbalanced workload, cooperative
//! `scope_blocking` (zero scoped spawns when pool capacity suffices),
//! helper shutdown draining, and engine lane dispatch routing through
//! worker-local deques.
//!
//! Every test takes the binary-local `guard()` lock: explicit pools,
//! helpers, and the `os_thread_spawns` / parked-worker assertions are
//! all sensitive to concurrent pool churn in the same process.

use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{Engine, MitigationRequest};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::pool::{self, scope_blocking, ThreadPool, UnsafeSlice};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Serializes every test in this binary (see the module docs).
fn guard() -> MutexGuard<'static, ()> {
    static GUARD: Mutex<()> = Mutex::new(());
    GUARD.lock().unwrap_or_else(|e| e.into_inner())
}

/// A cheap deterministic value per index, so schedule changes that
/// misroute a single write are caught.
fn mix(k: usize) -> u64 {
    let mut x = k as u64 ^ 0x9E37_79B9_7F4A_7C15;
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x
}

/// Spin until `flag` is set; panic (cleanly failing the test instead of
/// hanging it) after `secs` seconds.
fn spin_until(flag: &AtomicBool, secs: u64) {
    let t0 = Instant::now();
    while !flag.load(Ordering::SeqCst) {
        assert!(t0.elapsed() < Duration::from_secs(secs), "spin_until timed out");
        std::thread::yield_now();
    }
}

#[test]
fn nested_regions_bit_exact_across_thread_counts() {
    let _g = guard();
    // Outer `for_range` × inner `chunks_mut` — the pipeline's exact
    // nesting shape — must be bit-identical to sequential for every
    // thread-count combination, including heavy oversubscription
    // (4N threads on an N-lane pool).
    let lanes = 4usize;
    let pool = ThreadPool::new(lanes);
    let outer_n = 12usize;
    let inner_n = 64usize;
    let expect: Vec<u64> = (0..outer_n * inner_n).map(mix).collect();
    for &t_outer in &[1usize, 2, lanes, 4 * lanes] {
        for &t_inner in &[1usize, 2, lanes, 4 * lanes] {
            let mut out = vec![0u64; outer_n * inner_n];
            let s = UnsafeSlice::new(&mut out);
            pool.for_range(outer_n, t_outer, 1, |i| {
                // SAFETY: rows are disjoint per outer index.
                let row = unsafe { s.slice_mut(i * inner_n, inner_n) };
                pool.chunks_mut(row, t_inner, |start, chunk| {
                    for (k, v) in chunk.iter_mut().enumerate() {
                        *v = mix(i * inner_n + start + k);
                    }
                });
            });
            assert_eq!(out, expect, "t_outer={t_outer} t_inner={t_inner}");
        }
    }
}

#[test]
fn region_completes_while_all_workers_are_blocked_because_waiters_help() {
    let _g = guard();
    // Acceptance scenario: every worker is blocked, a region is
    // submitted, and it must still complete because a *waiting* thread
    // (here an explicit help_until loop standing in for any blocked
    // waiter) executes the queued tickets. The barrier couples the two
    // region items, so completion provably requires a second
    // participant — under the old single-injector scheduler with its
    // only worker blocked, this test deadlocks.
    let pool = Arc::new(ThreadPool::new(2)); // exactly one worker
    assert_eq!(pool.workers(), 1);

    // Deterministically occupy the worker.
    let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    pool.spawn(move || {
        started_tx.send(()).unwrap();
        let _ = release_rx.recv();
    });
    started_rx.recv_timeout(Duration::from_secs(30)).expect("blocker task must start");

    // A waiter lends its thread to the pool.
    let done = Arc::new(AtomicBool::new(false));
    let helper = pool.helper();
    let d = done.clone();
    let helper_thread = std::thread::spawn(move || helper.help_until(&d));

    let help_before = pool.counters().help_runs;
    let coupled = Barrier::new(2);
    let hits = std::sync::atomic::AtomicUsize::new(0);
    pool.for_range(2, 2, 1, |_| {
        coupled.wait(); // needs both items live at once
        hits.fetch_add(1, Ordering::SeqCst);
    });
    assert_eq!(hits.load(Ordering::SeqCst), 2);
    assert!(
        pool.counters().help_runs > help_before,
        "the second region item can only have run on a helping waiter"
    );

    done.store(true, Ordering::SeqCst);
    release_tx.send(()).unwrap();
    helper_thread.join().unwrap();
}

#[test]
fn worker_blocked_in_nested_wait_helps_complete_new_region() {
    let _g = guard();
    // The acceptance scenario verbatim: every worker is blocked in a
    // *nested region wait* when a fresh region is submitted — and the
    // fresh region still completes, because the nested waiter runs its
    // tickets (help_runs moves). Setup on a one-worker pool:
    //
    //   worker W: task T opens inner region R1, claims item 0 (which
    //             handshakes with item 1), finishes its share, and then
    //             waits for R1's straggler — W is "blocked in a nested
    //             wait".
    //   helper H: steals R1's second ticket and *stays inside the body*
    //             (the straggler) long enough for the whole test.
    //   main:     submits region R2, whose two items handshake — so R2
    //             can only complete if a second participant joins, and
    //             the only thread able to is W, helping from inside its
    //             nested wait.
    let pool = Arc::new(ThreadPool::new(2)); // exactly one worker
    assert_eq!(pool.workers(), 1);
    let b0_entered = Arc::new(AtomicBool::new(false));
    let r1_handshake = Arc::new(AtomicBool::new(false));
    let r2_handshake = AtomicBool::new(false);

    let done = Arc::new(AtomicBool::new(false));
    let helper = pool.helper();
    let d = done.clone();
    let h = std::thread::spawn(move || helper.help_until(&d));

    let p = pool.clone();
    let (t_tx, t_rx) = std::sync::mpsc::channel::<()>();
    {
        let b0_entered = b0_entered.clone();
        let r1_handshake = r1_handshake.clone();
        pool.spawn(move || {
            p.for_range(2, 2, 1, |i| {
                if i == 0 {
                    b0_entered.store(true, Ordering::SeqCst);
                    // Requires item 1 (stolen by H) to have started.
                    spin_until(&r1_handshake, 30);
                } else {
                    r1_handshake.store(true, Ordering::SeqCst);
                    // Straggler: keeps R1 open, so T sits in its nested
                    // wait while R2 below runs.
                    std::thread::sleep(Duration::from_secs(1));
                }
            });
            t_tx.send(()).unwrap();
        });
    }
    spin_until(&b0_entered, 30);
    std::thread::sleep(Duration::from_millis(30));

    let help_before = pool.counters().help_runs;
    pool.for_range(2, 2, 1, |i| {
        if i == 0 {
            spin_until(&r2_handshake, 30);
        } else {
            r2_handshake.store(true, Ordering::SeqCst);
        }
    });
    assert!(
        pool.counters().help_runs > help_before,
        "R2's second item can only have run via the nested waiter helping"
    );

    t_rx.recv_timeout(Duration::from_secs(30)).expect("nested region must drain");
    done.store(true, Ordering::SeqCst);
    h.join().unwrap();
}

#[test]
fn tasks_outnumber_workers_deadlock_reproducer_completes() {
    let _g = guard();
    // The deadlock class the refactor removes by construction: on a
    // one-worker pool, a detached task that spawns a second detached
    // task and then waits for it starves forever under the old
    // scheduler (the waiter owns the only worker; the second task can
    // never run). With cooperative blocking the waiter runs it itself.
    let pool = ThreadPool::new(2);
    assert_eq!(pool.workers(), 1);
    let helper = pool.helper();
    let t2_done = Arc::new(AtomicBool::new(false));
    let (tx, rx) = std::sync::mpsc::channel::<&'static str>();

    let inner_helper = helper.clone();
    let flag = t2_done.clone();
    pool.spawn(move || {
        let f2 = flag.clone();
        inner_helper.spawn(move || f2.store(true, Ordering::SeqCst));
        inner_helper.help_until(&flag); // waits for t2 — by running it
        tx.send("t1 finished").unwrap();
    });

    let got = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("tasks > workers must no longer deadlock: the waiter helps");
    assert_eq!(got, "t1 finished");
    assert!(t2_done.load(Ordering::SeqCst));
    assert!(pool.counters().help_runs > 0, "t2 must have run as a help ticket");
}

#[test]
fn imbalanced_workload_actually_steals() {
    let _g = guard();
    // A region opened from inside a worker publishes its tickets on
    // that worker's local deque; the other (idle) workers have nothing
    // in their own deques and an empty injector, so the only way they
    // can participate is to steal. Item 0 spins until the steal counter
    // moves, making the assertion deterministic (with a 10 s valve so
    // a regression fails instead of hanging).
    let pool = Arc::new(ThreadPool::new(4));
    assert_eq!(pool.workers(), 3);
    let steals_before = pool.counters().steals;
    let (tx, rx) = std::sync::mpsc::channel::<()>();
    let p = pool.clone();
    pool.spawn(move || {
        p.for_range(512, 4, 1, |i| {
            if i == 0 {
                let t0 = Instant::now();
                while p.counters().steals == steals_before
                    && t0.elapsed() < Duration::from_secs(10)
                {
                    std::thread::yield_now();
                }
            }
        });
        tx.send(()).unwrap();
    });
    rx.recv_timeout(Duration::from_secs(60)).expect("imbalanced region must complete");
    assert!(
        pool.counters().steals > steals_before,
        "idle workers must steal from the busy worker's deque"
    );
}

#[test]
fn scope_blocking_reserves_parked_workers_instead_of_spawning() {
    let _g = guard();
    // Cooperative scope_blocking: when enough global-pool workers are
    // parked, a mutually-blocking rank set spawns zero scoped OS
    // threads — the ranks run on reserved workers plus the caller.
    let global = pool::global();
    global.for_range(256, 4, 8, |_| {}); // force creation + warm
    if global.workers() < 2 {
        // A QAI_POOL_THREADS-constrained run cannot pin both extra
        // ranks; the spawn-free property is vacuous here.
        return;
    }
    // Workers re-park within one timeout period; retry around the
    // (tiny) window where a worker is between wake and re-park.
    let mut spawned = usize::MAX;
    for _ in 0..5 {
        std::thread::sleep(Duration::from_millis(60));
        let before = pool::os_thread_spawns();
        let barrier = Arc::new(Barrier::new(3));
        let tasks: Vec<_> = (0..3usize)
            .map(|rank| {
                let b = barrier.clone();
                move || {
                    b.wait(); // all three ranks must be live at once
                    rank * 10
                }
            })
            .collect();
        let got = scope_blocking(tasks);
        assert_eq!(got, vec![0, 10, 20]);
        spawned = pool::os_thread_spawns() - before;
        if spawned == 0 {
            break;
        }
    }
    assert_eq!(spawned, 0, "parked workers must absorb the rank set without OS spawns");
}

#[test]
fn engine_lane_dispatch_routes_through_worker_deques() {
    let _g = guard();
    // Detached job tickets from the admission scheduler land on
    // worker-local deques (round-robin), never on the injector: every
    // consumed ticket shows up as a local hit, a steal, or a help run.
    let pool = Arc::new(ThreadPool::new(3));
    let before = pool.counters();
    assert_eq!(before.injector_pops, 0);
    let engine = Engine::builder().pool(pool.clone()).build();
    let orig = generate(DatasetKind::CombustionLike, &[16, 16, 16], 3);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let requests: Vec<MitigationRequest> =
        (0..4).map(|_| MitigationRequest::new(dq.clone(), q.clone(), eb)).collect();
    let results = engine.run_batch(requests);
    assert!(results.iter().all(|r| r.is_ok()));
    let after = pool.counters();
    // Claim-source counters are exact (help_runs is an overlapping
    // attribution, so it is deliberately not part of this sum).
    let via_deques =
        (after.local_hits - before.local_hits) + (after.steals - before.steals);
    assert!(via_deques >= 4, "each of the 4 job tickets drains from a worker deque");
    assert_eq!(after.injector_pops, 0, "lane dispatch must bypass the injector");
}

#[test]
fn pool_drop_drains_helpers_without_running_stale_tickets() {
    let _g = guard();
    // Satellite regression, part 1: a helper parked inside help_until
    // when the pool drops must exit promptly.
    let pool = ThreadPool::new(1); // zero workers
    let parked_helper = pool.helper();
    let h = std::thread::spawn(move || {
        let never = AtomicBool::new(false);
        parked_helper.help_until(&never);
    });
    std::thread::sleep(Duration::from_millis(300));
    drop(pool);
    h.join().expect("parked helper must exit at pool shutdown");

    // Part 2: a ticket still queued at shutdown is stale — a helper
    // must refuse to start it. (Zero-worker pool, no helper running, so
    // the ticket is deterministically still queued when the pool
    // drops.)
    let pool = ThreadPool::new(1);
    let helper = pool.helper();
    let stale_ran = Arc::new(AtomicBool::new(false));
    let probe = stale_ran.clone();
    pool.spawn(move || probe.store(true, Ordering::SeqCst));
    drop(pool);
    let never = AtomicBool::new(false);
    helper.help_until(&never); // must return despite the unset flag
    assert!(!helper.try_help_one());
    assert!(
        !stale_ran.load(Ordering::SeqCst),
        "a ticket queued at shutdown must never run"
    );
}

#[test]
fn mitigation_stays_bit_exact_on_a_busy_stealing_pool() {
    let _g = guard();
    // End-to-end re-audit: the full pipeline, confined to a pool that
    // is concurrently churning unrelated detached tasks (so tickets
    // interleave across deques, steals, and helps), stays bit-identical
    // to the sequential reference.
    let orig = generate(DatasetKind::MirandaLike, &[20, 20, 20], 9);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let seq_req = MitigationRequest::new(dq.clone(), q.clone(), eb);
    let seq = qai::mitigation::engine::execute(&seq_req).unwrap().output;

    let pool = Arc::new(ThreadPool::new(4));
    let engine = Engine::builder().pool(pool.clone()).build();
    // Finite churn: each task opens 50 nested regions and exits, so the
    // deques keep interleaving churn tickets, stolen region tickets,
    // and the engine's job tickets while the rounds below run.
    for _ in 0..8 {
        let p = pool.clone();
        pool.spawn(move || {
            for _ in 0..50 {
                p.for_range(256, 2, 16, |i| {
                    std::hint::black_box(i);
                });
            }
        });
    }
    for round in 0..4 {
        let req = MitigationRequest::new(dq.clone(), q.clone(), eb).config(
            qai::mitigation::MitigationConfig { threads: 4, ..Default::default() },
        );
        let out = engine.run(req).unwrap().output;
        assert_eq!(out.data, seq.data, "round {round} diverged under contention");
    }
}
