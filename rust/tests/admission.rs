//! Streaming-admission integration tests: backpressure (`try_submit`
//! rejection at capacity, blocked `submit` completing on drain,
//! blocking-submit timeouts), priority ordering under contention,
//! deadline accounting, `ServiceStats` edge cases (zero-duration jobs,
//! rejected jobs, single-thread determinism), and shutdown
//! cancellation.
//!
//! Tests that need deterministic ordering use a **paused** service over
//! a **single-lane** private pool: nothing runs until `resume()`, and
//! with one lane the scheduler executes jobs inline, strictly in
//! dequeue order.

// The deprecated service constructors and `mitigate_with_stats` are
// exercised deliberately: this suite pins the legacy admission paths,
// now thin wrappers over the engine (see rust/tests/engine.rs for the
// typed front door).
#![allow(deprecated)]

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::{
    mitigate_with_stats, Job, MitigationConfig, MitigationService, Priority, ServiceConfig,
    SubmitError, SubmitOptions,
};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

fn make_job(dims: &[usize], seed: u64, threads: usize) -> Job {
    let orig = generate(DatasetKind::ClimateLike, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    Job::with_config(dq, q, eb, MitigationConfig { threads, ..Default::default() })
}

/// A tiny job whose pipeline is effectively zero-duration: a single
/// homogeneous element has no boundary, so mitigation is an early-out
/// identity.
fn zero_duration_job() -> Job {
    let dq = Grid::from_vec(vec![1.5f32], &[1]);
    let q = Grid::from_vec(vec![0i64], &[1]);
    let eb = ErrorBound::absolute(0.5).resolve(&dq.data);
    Job::new(dq, q, eb)
}

fn paused_service(lanes: usize, capacity: usize) -> MitigationService {
    MitigationService::with_config(ServiceConfig {
        pool: Some(Arc::new(ThreadPool::new(lanes))),
        capacity,
        start_paused: true,
        ..Default::default()
    })
}

#[test]
fn try_submit_returns_queue_full_at_capacity() {
    let service = paused_service(2, 3);
    let mut tickets = Vec::new();
    for seed in 0..3 {
        let job = make_job(&[16, 16], seed, 1);
        tickets.push(service.try_submit(job, SubmitOptions::bulk()).unwrap());
    }
    let err = service.try_submit(make_job(&[16, 16], 9, 1), SubmitOptions::bulk()).unwrap_err();
    assert!(matches!(err, SubmitError::QueueFull(_)), "got {err:?}");

    let st = service.stats();
    assert_eq!(st.submitted, 3);
    assert_eq!(st.rejected_full, 1);
    assert_eq!(st.queue_depth, 3);
    assert_eq!(st.max_queue_depth, 3);

    // The rejected job comes back intact and is admitted once the
    // queue drains.
    let recovered = err.into_job();
    service.resume();
    let late = service.submit(recovered, SubmitOptions::bulk()).unwrap();
    assert!(late.wait().result.is_ok());
    for t in tickets {
        assert!(t.wait().result.is_ok());
    }
    assert_eq!(service.stats().completed, 4);
}

#[test]
fn blocked_submit_completes_once_queue_drains() {
    let service = Arc::new(paused_service(2, 2));
    let early: Vec<_> = (0..2)
        .map(|seed| {
            service.try_submit(make_job(&[16, 16], seed, 1), SubmitOptions::bulk()).unwrap()
        })
        .collect();
    // Queue is full and paused: a blocking submit must park…
    let svc = service.clone();
    let blocked = std::thread::spawn(move || {
        svc.submit(make_job(&[16, 16], 7, 1), SubmitOptions::bulk()).map(|t| t.wait())
    });
    std::thread::sleep(Duration::from_millis(50));
    assert!(!blocked.is_finished(), "submit must block while the queue is full");
    assert!(!early[0].is_complete(), "paused service must not run jobs");

    // …until resuming drains the queue and frees a slot.
    service.resume();
    let report = blocked.join().unwrap().expect("blocked submit must succeed after the drain");
    assert!(report.result.is_ok());
    for t in early {
        assert!(t.wait().result.is_ok());
    }
}

#[test]
fn blocking_submit_times_out_when_full() {
    let service = paused_service(1, 1);
    let held = service.try_submit(make_job(&[12, 12], 1, 1), SubmitOptions::bulk()).unwrap();
    let opts = SubmitOptions::bulk().with_timeout(Duration::from_millis(40));
    let err = service.submit(make_job(&[12, 12], 2, 1), opts).unwrap_err();
    assert!(matches!(err, SubmitError::Timeout(_)), "got {err:?}");
    assert_eq!(service.stats().submit_timeouts, 1);
    drop(held);
}

#[test]
fn interactive_overtakes_queued_bulk() {
    // Single-lane pool: strictly sequential execution in dequeue order,
    // so the global sequence numbers fully capture the schedule.
    let service = paused_service(1, 16);
    let bulk: Vec<_> = (0..3)
        .map(|seed| {
            service.try_submit(make_job(&[20, 20], seed, 1), SubmitOptions::bulk()).unwrap()
        })
        .collect();
    let interactive: Vec<_> = (10..12)
        .map(|seed| {
            service.try_submit(make_job(&[20, 20], seed, 1), SubmitOptions::interactive()).unwrap()
        })
        .collect();
    service.resume();

    let bulk_seqs: Vec<u64> = bulk
        .into_iter()
        .map(|t| {
            let r = t.wait();
            assert_eq!(r.priority, Priority::Bulk);
            assert!(r.result.is_ok());
            r.seq
        })
        .collect();
    let interactive_seqs: Vec<u64> = interactive
        .into_iter()
        .map(|t| {
            let r = t.wait();
            assert_eq!(r.priority, Priority::Interactive);
            assert!(r.result.is_ok());
            r.seq
        })
        .collect();

    for &i in &interactive_seqs {
        for &b in &bulk_seqs {
            assert!(
                i < b,
                "interactive job (seq {i}) must be dequeued before queued bulk job (seq {b})"
            );
        }
    }
    let st = service.stats();
    assert_eq!(st.interactive_done, 2);
    assert_eq!(st.bulk_done, 3);
}

#[test]
fn queue_path_output_is_bit_identical_to_direct_call() {
    let service = paused_service(2, 8);
    let jobs: Vec<Job> = (0..4).map(|seed| make_job(&[24, 24], seed, 2)).collect();
    let tickets: Vec<_> = jobs
        .iter()
        .map(|j| service.try_submit(j.clone(), SubmitOptions::interactive()).unwrap())
        .collect();
    service.resume();
    for (job, ticket) in jobs.iter().zip(tickets) {
        let (queued, _) = ticket.wait().result.unwrap();
        let (direct, _) = mitigate_with_stats(&job.dq, &job.q, job.eb, &job.cfg).unwrap();
        assert_eq!(queued.data, direct.data, "queue path diverged from direct call");
    }
}

#[test]
fn deadline_accounting_hit_and_miss() {
    let service = MitigationService::with_config(ServiceConfig {
        pool: Some(Arc::new(ThreadPool::new(2))),
        capacity: 8,
        start_paused: false,
        ..Default::default()
    });

    let generous = SubmitOptions::bulk().with_deadline(Duration::from_secs(3600));
    let hit = service.submit(make_job(&[16, 16], 1, 1), generous).unwrap().wait();
    assert!(hit.result.is_ok());
    assert!(!hit.deadline_missed, "hour-long deadline cannot be missed");
    assert_eq!(hit.deadline, Some(Duration::from_secs(3600)));

    let impossible = SubmitOptions::interactive().with_deadline(Duration::ZERO);
    let miss = service.submit(make_job(&[16, 16], 2, 1), impossible).unwrap().wait();
    assert!(miss.result.is_ok(), "an overrun job still completes");
    assert!(miss.deadline_missed, "zero deadline is always missed");

    let no_deadline =
        service.submit(make_job(&[16, 16], 3, 1), SubmitOptions::bulk()).unwrap().wait();
    assert!(!no_deadline.deadline_missed);
    assert_eq!(no_deadline.deadline, None);

    let st = service.stats();
    assert_eq!(st.deadlines_set, 2);
    assert_eq!(st.deadlines_missed, 1);
    assert_eq!(st.completed, 3);
}

#[test]
fn zero_duration_jobs_keep_stats_sane() {
    let service = paused_service(1, 8);
    let tickets: Vec<_> = (0..3)
        .map(|_| {
            service
                .try_submit(
                    zero_duration_job(),
                    SubmitOptions::bulk().with_deadline(Duration::from_secs(60)),
                )
                .unwrap()
        })
        .collect();
    service.resume();
    for t in tickets {
        let report = t.wait();
        let (out, stats) = report.result.unwrap();
        assert_eq!(out.data, vec![1.5f32], "homogeneous 1-element job must be identity");
        assert_eq!(stats.n_boundary1, 0);
        assert!(!report.deadline_missed);
    }
    let st = service.stats();
    assert_eq!(st.completed, 3);
    assert_eq!(st.failed, 0);
    assert_eq!(st.deadlines_set, 3);
    assert_eq!(st.deadlines_missed, 0);
    assert_eq!(st.queue_depth, 0);
    assert!(st.total_exec_s >= 0.0);
    assert!(st.total_queue_wait_s >= 0.0);
}

#[test]
fn stats_counters_deterministic_under_single_thread() {
    let run = || {
        let service = paused_service(1, 8);
        let mut tickets = Vec::new();
        for seed in 0..2 {
            let job = make_job(&[18, 18], seed, 1);
            tickets.push(service.try_submit(job, SubmitOptions::bulk()).unwrap());
        }
        tickets.push(
            service.try_submit(make_job(&[18, 18], 5, 1), SubmitOptions::interactive()).unwrap(),
        );
        // A shape-mismatched job: fails deterministically.
        let mut bad = make_job(&[18, 18], 6, 1);
        bad.q = Grid::from_vec(vec![0i64; 4], &[2, 2]).into();
        tickets.push(service.try_submit(bad, SubmitOptions::bulk()).unwrap());
        // Over-capacity rejection: deterministic counter bump.
        let service_full = paused_service(1, 1);
        service_full.try_submit(zero_duration_job(), SubmitOptions::bulk()).unwrap();
        let rejected =
            service_full.try_submit(zero_duration_job(), SubmitOptions::bulk()).unwrap_err();
        assert!(matches!(rejected, SubmitError::QueueFull(_)));

        service.resume();
        service_full.resume();
        let outputs: Vec<Option<Vec<f32>>> = tickets
            .into_iter()
            .map(|t| t.wait().result.ok().map(|(g, _)| g.data))
            .collect();
        let st = service.stats();
        let counters = (
            st.submitted,
            st.rejected_full,
            st.completed,
            st.failed,
            st.interactive_done,
            st.bulk_done,
            st.max_queue_depth,
            service_full.stats().rejected_full,
        );
        (counters, outputs)
    };

    let (c1, o1) = run();
    let (c2, o2) = run();
    assert_eq!(c1, c2, "stats counters must be deterministic under threads == 1");
    assert_eq!(o1, o2, "outputs must be bitwise deterministic");
    assert_eq!(c1.0, 4); // submitted
    assert_eq!(c1.2, 3); // completed
    assert_eq!(c1.3, 1); // failed (shape mismatch)
    assert_eq!(c1.7, 1); // rejected on the capacity-1 service
}

#[test]
fn shutdown_cancels_queued_jobs_and_resolves_tickets() {
    let service = paused_service(1, 8);
    let ticket = service.try_submit(make_job(&[16, 16], 1, 1), SubmitOptions::bulk()).unwrap();
    let stats_before = service.stats();
    assert_eq!(stats_before.queue_depth, 1);
    drop(service);
    let report = ticket.wait();
    let err = report.result.unwrap_err().to_string();
    assert!(err.contains("shut down"), "err={err}");
    assert_eq!(report.seq, u64::MAX, "cancelled jobs were never scheduled");
}

#[test]
fn scheduler_sleeps_without_polling_when_idle() {
    // Regression: the idle lane-wait used to spin on a 5 ms
    // `wait_timeout` whose result was discarded — an idle shard woke
    // its scheduler ~200 times/s forever. The wait is now an untimed
    // condvar park, so an idle queue must produce zero wakeups.
    let service = MitigationService::with_config(ServiceConfig {
        pool: Some(Arc::new(ThreadPool::new(2))),
        capacity: 4,
        start_paused: false,
        ..Default::default()
    });
    let report = service.submit(zero_duration_job(), SubmitOptions::bulk()).unwrap().wait();
    assert!(report.result.is_ok());
    // Let the scheduler finish its post-job bookkeeping and park.
    std::thread::sleep(Duration::from_millis(50));
    let before = service.stats().sched_wakeups;
    std::thread::sleep(Duration::from_millis(150));
    let after = service.stats().sched_wakeups;
    assert_eq!(before, after, "idle scheduler must park on the condvar, not poll");
}

#[test]
fn zero_timeout_blocking_submit_fails_cleanly_when_full() {
    // Regression: the blocking-submit wait loop computed
    // `give_up - now` after re-reading `now`, which panics when the
    // deadline has just passed; it now uses `checked_duration_since`
    // and reports a clean timeout. A zero timeout is the tightest
    // trigger for that race.
    let service = paused_service(1, 1);
    let held = service.try_submit(zero_duration_job(), SubmitOptions::bulk()).unwrap();
    let opts = SubmitOptions::bulk().with_timeout(Duration::ZERO);
    let err = service.submit(zero_duration_job(), opts).unwrap_err();
    assert!(matches!(err, SubmitError::Timeout(_)), "got {err:?}");
    assert_eq!(service.stats().submit_timeouts, 1);
    drop(held);
}

#[test]
fn try_wait_and_wait_timeout_roundtrip() {
    let service = paused_service(1, 4);
    let ticket = service.try_submit(make_job(&[16, 16], 4, 1), SubmitOptions::bulk()).unwrap();
    // Paused: the job cannot be done yet.
    let ticket = ticket.try_wait().expect_err("job must not have run while paused");
    let ticket = match ticket.wait_timeout(Duration::from_millis(30)) {
        Err(t) => t,
        Ok(_) => panic!("paused job must not complete within the timeout"),
    };
    service.resume();
    let report = ticket.wait();
    assert!(report.result.is_ok());
}
