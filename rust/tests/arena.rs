//! Zero-copy data-plane proofs: arena-backed scratch reuse and
//! `Arc`-backed job payloads.
//!
//! Three invariant families:
//!
//! 1. **Bit-exactness** — the arena path produces byte-identical
//!    outputs to the fresh-allocation path, cold and warm, across
//!    datasets × dims × thread counts (buffer recycling must be purely
//!    an allocator optimization).
//! 2. **Warm-path allocation proof** — a second same-shaped job through
//!    one service performs zero new full-grid allocations (arena miss
//!    counter unchanged), the arena analog of the pool runtime's
//!    `os_thread_spawns` trick.
//! 3. **Zero-copy submission** — `submit` / `mitigate_batch` move `Arc`
//!    pointers, never grid bytes, observable through `SharedGrid`
//!    pointer identity and handle counts; and the lease accounting
//!    drains to zero (no leaks) once jobs are done.

// The deprecated service constructors and `mitigate_with_stats` are
// the legacy references this suite compares the arena path against.
#![allow(deprecated)]

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::pipeline::mitigate_with_stats;
use qai::mitigation::{Job, MitigationConfig, MitigationService, ServiceConfig, SubmitOptions};
use qai::quant::{quantize_grid, ErrorBound, ResolvedBound};
use qai::util::arena::{Arena, ArenaHandle};
use qai::util::pool::PoolHandle;

fn field(kind: DatasetKind, dims: &[usize], seed: u64) -> (Grid<f32>, Grid<i64>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (dq, q, eb)
}

#[test]
fn arena_path_is_bit_exact_across_datasets_dims_threads() {
    let cases: &[(DatasetKind, &[usize])] = &[
        (DatasetKind::ClimateLike, &[40, 40]),
        (DatasetKind::MirandaLike, &[18, 18, 18]),
        (DatasetKind::CombustionLike, &[14, 14, 14]),
        (DatasetKind::HurricaneLike, &[200]),
    ];
    for &(kind, dims) in cases {
        let (dq, q, eb) = field(kind, dims, 9);
        for threads in [1usize, 4] {
            let cfg = MitigationConfig { threads, ..Default::default() };
            let (fresh, fresh_stats) = mitigate_with_stats(&dq, &q, eb, &cfg).unwrap();
            let request = MitigationRequest::new(dq.clone(), q.clone(), eb)
                .config(cfg)
                .with_stats(true);
            let arena = Arena::new();
            // Cold pass (populates the free lists), then a warm pass
            // that runs entirely on recycled buffers — through the
            // engine's confined execution front door.
            for pass in 0..2 {
                let resp = engine::execute_on(
                    PoolHandle::Global,
                    ArenaHandle::Pooled(&arena),
                    &request,
                )
                .unwrap();
                let stats = resp.stats.expect("stats requested");
                assert_eq!(
                    resp.output.data, fresh.data,
                    "kind={kind:?} dims={dims:?} threads={threads} pass={pass}"
                );
                assert_eq!(stats.n_boundary1, fresh_stats.n_boundary1);
                assert_eq!(stats.n_boundary2, fresh_stats.n_boundary2);
            }
            assert!(arena.stats().hits > 0, "warm pass must reuse buffers");
        }
    }
}

#[test]
fn near_shapes_share_rounded_size_classes() {
    // A 24^3 field and a 25x24x24 near-shape round to the same
    // power-of-two classes (16384 full-grid, 32 per-line), so a warm
    // near-shaped job allocates zero new full-grid buffers — the point
    // of size-class rounding. Outputs stay bit-identical to the fresh
    // path for both shapes.
    let (dq_a, q_a, eb_a) = field(DatasetKind::MirandaLike, &[24, 24, 24], 5);
    let (dq_b, q_b, eb_b) = field(DatasetKind::MirandaLike, &[25, 24, 24], 6);
    let cfg = MitigationConfig::default();
    let (fresh_a, _) = mitigate_with_stats(&dq_a, &q_a, eb_a, &cfg).unwrap();
    let (fresh_b, _) = mitigate_with_stats(&dq_b, &q_b, eb_b, &cfg).unwrap();

    let arena = Arena::new();
    let run = |dq: &Grid<f32>, q: &Grid<i64>, eb: ResolvedBound| {
        let request = MitigationRequest::new(dq.clone(), q.clone(), eb).config(cfg);
        let resp =
            engine::execute_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &request).unwrap();
        resp.output
    };

    // Cold pass on shape A populates the rounded classes; recycle the
    // output so the B job's output buffer is covered too.
    let out_a = run(&dq_a, &q_a, eb_a);
    assert_eq!(out_a.data, fresh_a.data);
    arena.adopt(out_a.data);
    let cold = arena.stats();
    assert!(cold.misses > 0, "cold pass must have populated the arena");

    // Near-shape B: every take lands in a class A already filled.
    let out_b = run(&dq_b, &q_b, eb_b);
    assert_eq!(out_b.data, fresh_b.data, "rounded-class reuse must stay bit-exact");
    let warm = arena.stats();
    assert_eq!(
        warm.misses, cold.misses,
        "a near-shaped job must allocate zero new full-grid buffers \
         (rounded classes must absorb the shape delta)"
    );
    assert!(warm.hits > cold.hits, "the near-shaped job must draw from the free lists");
}

#[test]
fn warm_repeat_job_allocates_zero_full_grid_buffers() {
    let (dq, q, eb) = field(DatasetKind::MirandaLike, &[24, 24, 24], 5);
    let job = Job::new(dq.clone(), q.clone(), eb);
    let (reference, _) = mitigate_with_stats(&dq, &q, eb, &job.cfg).unwrap();

    let service = MitigationService::new();
    let out1 = service
        .submit(job.clone(), SubmitOptions::bulk())
        .unwrap()
        .wait()
        .result
        .unwrap()
        .0;
    assert_eq!(out1.data, reference.data);
    // Hand the output buffer back so the warm job's output is
    // allocation-free too.
    service.recycle(out1);

    let cold = service.arena_stats();
    assert!(cold.misses > 0, "the cold job must have populated the arena");

    let out2 = service
        .submit(job, SubmitOptions::bulk())
        .unwrap()
        .wait()
        .result
        .unwrap()
        .0;
    let warm = service.arena_stats();
    assert_eq!(
        warm.misses, cold.misses,
        "a warm same-shaped job must allocate zero new full-grid buffers"
    );
    assert!(warm.hits > cold.hits, "the warm job must have drawn from the free lists");
    assert_eq!(out2.data, reference.data, "warm output must stay bit-identical");
}

#[test]
fn lease_accounting_returns_to_zero_and_survives_service_drop() {
    let service = MitigationService::new();
    let arena = service.arena();
    let mut results = Vec::new();
    for (dims, seed) in [(&[20, 20, 20][..], 1u64), (&[16, 16][..], 2), (&[20, 20, 20][..], 3)] {
        let (dq, q, eb) = field(DatasetKind::CombustionLike, dims, seed);
        let ticket = service.submit(Job::new(dq, q, eb), SubmitOptions::bulk()).unwrap();
        results.push(ticket.wait().result.unwrap().0);
    }
    let st = arena.stats();
    assert_eq!(
        st.bytes_outstanding, 0,
        "every intermediate lease must be back once all jobs completed"
    );
    assert_eq!(st.detached as usize, results.len(), "one detached output per job");
    drop(service);
    // The kept handle still observes the (quiescent) counters.
    let st = arena.stats();
    assert_eq!(st.bytes_outstanding, 0, "no leases may leak across service shutdown");
    assert_eq!(st.returns + st.detached, st.hits + st.misses, "takes must balance");
}

#[test]
fn job_clone_and_requeue_share_grid_allocations() {
    let (dq, q, eb) = field(DatasetKind::ClimateLike, &[16, 16], 7);
    let job = Job::new(dq, q, eb);
    let twin = job.clone();
    assert!(job.dq.ptr_eq(&twin.dq), "Job::clone must share the data grid");
    assert!(job.q.ptr_eq(&twin.q), "Job::clone must share the index grid");

    // A rejected submission hands back the very same allocation.
    let service = MitigationService::with_config(ServiceConfig {
        capacity: 1,
        start_paused: true,
        ..Default::default()
    });
    let _queued = service.try_submit(job, SubmitOptions::bulk()).unwrap();
    let bounced = service.try_submit(twin.clone(), SubmitOptions::bulk()).unwrap_err().into_job();
    assert!(bounced.dq.ptr_eq(&twin.dq), "a bounced job must carry the original payload");
    drop(service); // cancels the queued job
}

#[test]
fn submit_and_batch_move_pointers_not_grid_bytes() {
    // A queued job holds a second handle to the caller's allocation —
    // a deep copy would leave the caller's handle count at one.
    let (dq, q, eb) = field(DatasetKind::ClimateLike, &[12, 12], 3);
    let job = Job::new(dq, q, eb);
    let service = MitigationService::with_config(ServiceConfig {
        capacity: 4,
        start_paused: true,
        ..Default::default()
    });
    assert_eq!(job.dq.handle_count(), 1);
    let ticket = service.submit(job.clone(), SubmitOptions::bulk()).unwrap();
    assert_eq!(job.dq.handle_count(), 2, "submit must move the Arc, not copy the grid");
    assert_eq!(job.q.handle_count(), 2);
    service.resume();
    assert!(ticket.wait().result.is_ok());
    // The job task may hold its handle for a few more instructions
    // after resolving the ticket; poll briefly.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    while job.dq.handle_count() != 1 {
        assert!(
            std::time::Instant::now() < deadline,
            "the service must drop its handle after the job"
        );
        std::thread::yield_now();
    }

    // Same through the compat batch wrapper, mid-flight on a paused
    // service drained from another thread.
    let service = MitigationService::with_config(ServiceConfig {
        capacity: 4,
        start_paused: true,
        ..Default::default()
    });
    let batch = vec![job.clone()];
    let waiter = {
        let service = &service;
        let batch = &batch;
        std::thread::scope(|s| {
            let handle = s.spawn(move || service.mitigate_batch(batch));
            // Wait until the batch job is queued, then observe sharing.
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
            while service.stats().submitted < 1 {
                assert!(std::time::Instant::now() < deadline, "job never queued");
                std::thread::yield_now();
            }
            assert_eq!(
                job.dq.handle_count(),
                3, // caller's `job` + `batch` slot + the queued clone
                "mitigate_batch must clone pointers, not grid data"
            );
            service.resume();
            handle.join().expect("batch thread")
        })
    };
    assert!(waiter[0].is_ok());
}

#[test]
fn block_decoders_reuse_buffers_bit_exactly() {
    use qai::compressors::{szp::SzpLike, Compressor};

    let orig = generate(DatasetKind::CosmologyLike, &[24, 24, 24], 11);
    let eb = ErrorBound::relative(1e-3).resolve(&orig.data);
    let codec = SzpLike { threads: 2 };
    let stream = codec.compress(&orig, eb).unwrap();
    let fresh = codec.decompress(&stream).unwrap();

    let arena = Arena::new();
    let d1 = codec.decompress_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream).unwrap();
    assert_eq!(d1.grid.data, fresh.grid.data);
    assert_eq!(d1.quant_indices.data, fresh.quant_indices.data);
    let cold_misses = arena.stats().misses;
    // Recycle the outputs; the next decode of the same stream must not
    // allocate any full-grid buffer.
    arena.adopt(d1.grid.data);
    arena.adopt(d1.quant_indices.data);
    let d2 = codec.decompress_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream).unwrap();
    assert_eq!(d2.grid.data, fresh.grid.data);
    assert_eq!(d2.quant_indices.data, fresh.quant_indices.data);
    let st = arena.stats();
    assert_eq!(st.misses, cold_misses, "warm SZp decode must be allocation-free");
    assert_eq!(st.bytes_outstanding, 0);
}

#[test]
fn sz3_decoder_reuses_buffers_bit_exactly() {
    use qai::compressors::sz3::Sz3Like;

    let orig = generate(DatasetKind::TurbulenceLike, &[18, 18, 18], 13);
    let eb = ErrorBound::relative(1e-3).resolve(&orig.data);
    let codec = Sz3Like { threads: 2 };
    let stream = codec.compress(&orig, eb).unwrap();
    let fresh = codec.decompress(&stream).unwrap();

    let arena = Arena::new();
    let d1 = codec.decompress_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream).unwrap();
    assert_eq!(d1.data, fresh.data);
    let cold_misses = arena.stats().misses;
    arena.adopt(d1.data);
    let d2 = codec.decompress_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &stream).unwrap();
    assert_eq!(d2.data, fresh.data);
    let st = arena.stats();
    assert_eq!(st.misses, cold_misses, "warm SZ3 decode must be allocation-free");
    assert_eq!(st.bytes_outstanding, 0);
}

#[test]
fn metrics_line_is_scrapeable_key_value_text() {
    let service = MitigationService::new();
    let (dq, q, eb) = field(DatasetKind::ClimateLike, &[16, 16], 21);
    let out = service
        .submit(Job::new(dq, q, eb), SubmitOptions::bulk())
        .unwrap()
        .wait()
        .result
        .unwrap()
        .0;
    service.recycle(out);
    let line = service.metrics_text();
    assert!(!line.contains('\n'), "metrics must be a single line");
    for token in line.split_whitespace() {
        let (key, value) = token.split_once('=').expect("key=value tokens");
        assert!(!key.is_empty() && !value.is_empty(), "token {token:?}");
    }
    assert!(line.contains("submitted=1"), "line={line}");
    assert!(line.contains("completed=1"), "line={line}");
    assert!(line.contains("arena_misses="), "line={line}");
    assert!(line.contains("arena_adopted=1"), "line={line}");
    assert!(line.contains("arena_bytes_outstanding=0"), "line={line}");
    // The high-water mark survives the job: scratch was leased and
    // returned, so outstanding is 0 but the peak stays visible.
    let peak: u64 = line
        .split_whitespace()
        .find_map(|t| t.strip_prefix("arena_bytes_peak="))
        .expect("arena_bytes_peak token")
        .parse()
        .unwrap();
    assert!(peak > 0, "pipeline scratch must register a high-water mark, line={line}");
}
