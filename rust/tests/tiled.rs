//! Tiled streaming executor: exactness, lane invariance, halo
//! geometry, and the arena-counter-proven scratch budget.
//!
//! The contracts under test (see `rust/src/mitigation/tiled.rs`):
//!
//! * **Lane invariance** — tiled output is bit-identical at every
//!   thread count (windows run sequentially inside; parallelism lives
//!   across tiles only).
//! * **Whole-field anchor** — `halo ≥ max(dims)` makes every window the
//!   whole field, so the tiled output bit-matches `run_pipeline`
//!   unconditionally, at any tile shape and thread count.
//! * **Bounded seam deviation** — at *any* halo, step E never
//!   compensates a point by more than `η·ε`, so tiled and whole-field
//!   outputs agree within `2·η·ε` pointwise and both stay inside the
//!   paper's relaxed bound `(1+η)·ε` against the original.
//! * **Scratch budget** — a pooled-arena tiled run keeps the arena's
//!   `bytes_peak` high-water mark under
//!   `TiledConfig::scratch_budget_bytes(field, lanes)`, and a warm
//!   rerun is allocation-free.
//! * **Streaming** — `run_tiled_szp` decodes per-tile windows out of
//!   the SZp stream, delivers every tile exactly once, and its
//!   first-tile latency never exceeds the total.

use qai::data::grid::{Grid, Shape};
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{execute_on, Engine, MitigationRequest};
use qai::mitigation::tiled::{plan, run_tiled_observed, run_tiled_szp, TiledConfig};
use qai::mitigation::MitigationConfig;
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};
use qai::util::arena::{Arena, ArenaHandle};
use qai::util::pool::PoolHandle;
use std::sync::Mutex;

fn prepared(
    kind: DatasetKind,
    dims: &[usize],
    seed: u64,
) -> (Grid<f32>, Grid<f32>, Grid<QIndex>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (orig, dq, q, eb)
}

fn whole_field(dq: &Grid<f32>, q: &Grid<QIndex>, eb: ResolvedBound) -> Grid<f32> {
    // The exact engine substrate the tiled path is measured against.
    let cfg = MitigationConfig { threads: 1, ..Default::default() };
    let job = qai::mitigation::Job::with_config(dq.clone(), q.clone(), eb, cfg);
    execute_on(PoolHandle::Global, ArenaHandle::Fresh, &MitigationRequest::from_job(job))
        .unwrap()
        .output
}

fn tiled_output(
    dq: &Grid<f32>,
    q: &Grid<QIndex>,
    eb: ResolvedBound,
    tiled: TiledConfig,
    threads: usize,
) -> Grid<f32> {
    let cfg = MitigationConfig { threads, ..Default::default() };
    let job = qai::mitigation::Job::with_config(dq.clone(), q.clone(), eb, cfg);
    execute_on(
        PoolHandle::Global,
        ArenaHandle::Fresh,
        &MitigationRequest::from_job(job).tiled(tiled),
    )
    .unwrap()
    .output
}

/// datasets × dimensionality × tile shapes: at every thread count the
/// tiled output is bit-identical to threads=1 tiled (lane invariance),
/// and with a whole-field halo it bit-matches the dense pipeline.
#[test]
fn lane_invariance_and_whole_field_anchor() {
    let cases: &[(DatasetKind, &[usize], &[usize], u64)] = &[
        (DatasetKind::ClimateLike, &[48, 48], &[16, 16], 11),
        (DatasetKind::CosmologyLike, &[33, 47], &[16, 12], 12),
        (DatasetKind::MirandaLike, &[16, 16, 12], &[8, 8, 8], 13),
        (DatasetKind::TurbulenceLike, &[12, 18, 14], &[5, 7, 6], 14),
    ];
    for &(kind, dims, tile, seed) in cases {
        let (_, dq, q, eb) = prepared(kind, dims, seed);
        let max_dim = *dims.iter().max().unwrap();

        // Whole-field halo ⇒ unconditional bit-identity.
        let anchor = TiledConfig::new(tile).with_halo(max_dim);
        let whole = whole_field(&dq, &q, eb);
        for threads in [1usize, 2, 4] {
            let got = tiled_output(&dq, &q, eb, anchor, threads);
            assert_eq!(
                got.data, whole.data,
                "{kind:?} {dims:?} tile={tile:?} threads={threads}: whole-field-halo tiled \
                 run must bit-match the dense pipeline"
            );
        }

        // Default halo: output must not depend on the lane count.
        let small = TiledConfig::new(tile);
        let seq = tiled_output(&dq, &q, eb, small, 1);
        for threads in [2usize, 4] {
            let par = tiled_output(&dq, &q, eb, small, threads);
            assert_eq!(
                par.data, seq.data,
                "{kind:?} {dims:?} tile={tile:?} threads={threads}: tiled output must be \
                 lane-count invariant"
            );
        }
    }
}

/// At *any* halo — including a deliberately undersized one — seam
/// disagreement with the dense pipeline is bounded by 2·η·ε (each path
/// compensates each point by at most η·ε), and the tiled output still
/// honors the paper's relaxed error bound against the original.
#[test]
fn undersized_halo_bounds_seam_deviation_and_error() {
    let cases: &[(DatasetKind, &[usize], &[usize], usize, u64)] = &[
        (DatasetKind::ClimateLike, &[40, 40], &[16, 16], 2, 21),
        (DatasetKind::CombustionLike, &[14, 20, 16], &[7, 10, 8], 1, 22),
        (DatasetKind::MirandaLike, &[18, 14, 12], &[9, 7, 6], 3, 23),
    ];
    for &(kind, dims, tile, halo, seed) in cases {
        let (orig, dq, q, eb) = prepared(kind, dims, seed);
        let eta = MitigationConfig::default().eta;
        let whole = whole_field(&dq, &q, eb);
        let got = tiled_output(&dq, &q, eb, TiledConfig::new(tile).with_halo(halo), 2);

        let seam_cap = 2.0 * eta * eb.abs * (1.0 + 1e-5) + 1e-12;
        let err_cap = (1.0 + eta) * eb.abs * (1.0 + 1e-5) + 1e-12;
        for i in 0..got.data.len() {
            let seam = (got.data[i] as f64 - whole.data[i] as f64).abs();
            assert!(
                seam <= seam_cap,
                "{kind:?} {dims:?} halo={halo}: seam deviation {seam:.3e} exceeds 2ηε={seam_cap:.3e} at {i}"
            );
            let err = (got.data[i] as f64 - orig.data[i] as f64).abs();
            assert!(
                err <= err_cap,
                "{kind:?} {dims:?} halo={halo}: |out-orig|={err:.3e} exceeds (1+η)ε={err_cap:.3e} at {i}"
            );
        }
    }
}

/// Window geometry: interior tiles carry the full halo margin on every
/// side; domain-edge tiles are shrink-clamped (margin = distance to the
/// domain edge). This is the tile-level analogue of the coordinator's
/// clamped halo exchange.
#[test]
fn halo_margins_full_inside_clamped_at_domain_edges() {
    let field = Shape::new(&[50, 30, 20]);
    let tiled = TiledConfig::new(&[16, 10, 8]).with_halo(4);
    for tp in plan(&field, &tiled) {
        for a in 0..3 {
            let lo_margin = tp.lo[a] - tp.window_lo[a];
            let hi_margin = (tp.window_lo[a] + tp.window_size[a]) - (tp.lo[a] + tp.size[a]);
            let want_lo = tiled.halo.min(tp.lo[a]);
            let want_hi = tiled.halo.min(field.dims[a] - tp.lo[a] - tp.size[a]);
            assert_eq!(lo_margin, want_lo, "tile {:?} axis {a} low margin", tp.lo);
            assert_eq!(hi_margin, want_hi, "tile {:?} axis {a} high margin", tp.lo);
        }
    }
}

/// The acceptance invariant: a tiled run on a field ≥ 8× the tile size
/// keeps the arena's high-water mark under the published budget
/// `window_elems × SCRATCH_BYTES_PER_ELEM × lanes`, outstanding bytes
/// return to zero, and a warm rerun allocates nothing new.
#[test]
fn pooled_scratch_stays_under_budget_and_warm_runs_are_allocation_free() {
    let dims = [64usize, 64];
    let (_, dq, q, eb) = prepared(DatasetKind::ClimateLike, &dims, 31);
    let lanes = 2usize;
    let tiled = TiledConfig::new(&[16, 16]); // 16 tiles = 16× tile count
    let cfg = MitigationConfig { threads: lanes, ..Default::default() };
    let job = qai::mitigation::Job::with_config(dq.clone(), q.clone(), eb, cfg);
    let request = MitigationRequest::from_job(job).tiled(tiled);

    let arena = Arena::new();
    let cold =
        execute_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &request).unwrap().output;
    let cold_stats = arena.stats();
    assert_eq!(cold_stats.bytes_outstanding, 0, "all window scratch must return to the pool");
    let budget = tiled.scratch_budget_bytes(&dq.shape, lanes);
    assert!(
        cold_stats.bytes_peak <= budget,
        "peak scratch {} B exceeds the tiled budget {} B (window_elems={} lanes={lanes})",
        cold_stats.bytes_peak,
        budget,
        tiled.window_elems(&dq.shape)
    );
    assert!(cold_stats.bytes_peak > 0, "a pooled run must register a high-water mark");

    let warm =
        execute_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &request).unwrap().output;
    assert_eq!(warm.data, cold.data, "warm rerun must be bit-identical");
    let warm_stats = arena.stats();
    assert_eq!(
        warm_stats.misses, cold_stats.misses,
        "warm tiled rerun must be allocation-free (every window buffer recycled)"
    );
    assert!(
        warm_stats.bytes_peak <= budget,
        "warm peak {} B exceeds budget {} B",
        warm_stats.bytes_peak,
        budget
    );
}

/// Front-door wiring: an engine built with a default `TiledConfig`
/// applies it to targetless requests (whole-field halo ⇒ bit-identity
/// with a plain engine), a per-request `tile_shape` works without the
/// builder default, and quality-targeted requests keep the dense path.
#[test]
fn engine_dispatches_tiled_requests() {
    let (orig, dq, q, eb) = prepared(DatasetKind::MirandaLike, &[14, 12, 10], 41);
    let plain = Engine::builder().build();
    let whole = plain.run(MitigationRequest::new(dq.clone(), q.clone(), eb)).unwrap().output;

    let tiled_engine =
        Engine::builder().tiled(TiledConfig::new(&[6, 6, 6]).with_halo(14)).build();
    let via_default =
        tiled_engine.run(MitigationRequest::new(dq.clone(), q.clone(), eb)).unwrap().output;
    assert_eq!(via_default.data, whole.data, "builder-default tiling must bit-match");

    let via_request = plain
        .run(
            MitigationRequest::new(dq.clone(), q.clone(), eb)
                .tiled(TiledConfig::new(&[5, 6, 4]).with_halo(14)),
        )
        .unwrap()
        .output;
    assert_eq!(via_request.data, whole.data, "per-request tiling must bit-match");

    // Quality-targeted jobs ignore tiling (the tuner owns the path) and
    // still satisfy the target machinery end-to-end.
    let resp = tiled_engine
        .run(
            MitigationRequest::new(dq.clone(), q.clone(), eb)
                .reference(orig)
                .quality_target(qai::mitigation::QualityTarget::Psnr(10.0)),
        )
        .unwrap();
    assert!(resp.quality.is_some(), "quality-targeted request must be scored");
}

/// Streaming fusion: decode-per-tile out of an SZp stream, every tile
/// delivered exactly once, first-tile latency ≤ total, and with a
/// whole-field halo the result bit-matches decompress-then-mitigate.
#[test]
fn szp_streaming_run_matches_decode_then_mitigate() {
    use qai::compressors::szp::SzpLike;
    use qai::compressors::Compressor;

    let orig = generate(DatasetKind::TurbulenceLike, &[24, 20, 8], 51);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let codec = SzpLike::default();
    let stream = codec.compress(&orig, eb).unwrap();

    let dec = codec.decompress(&stream).unwrap();
    let whole = whole_field(&dec.grid, &dec.quant_indices, dec.bound);

    let cfg = MitigationConfig { threads: 2, ..Default::default() };
    let tiled = TiledConfig::new(&[12, 10, 8]).with_halo(24);
    let arena = Arena::new();
    let seen = Mutex::new(Vec::<usize>::new());
    let outcome = run_tiled_szp(
        PoolHandle::Global,
        ArenaHandle::Pooled(&arena),
        &codec,
        &stream,
        &cfg,
        &tiled,
        &|d| seen.lock().unwrap().push(d.index),
    )
    .unwrap();

    assert_eq!(outcome.output.data, whole.data, "streaming run must bit-match");
    assert_eq!(outcome.bound.abs, dec.bound.abs);
    let n_tiles = plan(&outcome.output.shape, &tiled).len();
    assert_eq!(outcome.tiles, n_tiles);
    let mut seen = seen.into_inner().unwrap();
    seen.sort_unstable();
    assert_eq!(seen, (0..n_tiles).collect::<Vec<_>>(), "each tile delivered exactly once");
    assert!(outcome.first_tile <= outcome.total);
    assert_eq!(arena.stats().bytes_outstanding, 0);
}

/// The observer fires once per tile on the in-memory path too, and the
/// reported tile origins/extents partition the field.
#[test]
fn observer_reports_every_tile_once() {
    let (_, dq, q, eb) = prepared(DatasetKind::CosmologyLike, &[30, 26], 61);
    let cfg = MitigationConfig { threads: 4, ..Default::default() };
    let tiled = TiledConfig::new(&[8, 8]).with_halo(3);
    let events = Mutex::new(Vec::new());
    let (out, _) = run_tiled_observed(
        PoolHandle::Global,
        ArenaHandle::Fresh,
        &dq,
        &q,
        eb,
        &cfg,
        &tiled,
        &|d| events.lock().unwrap().push(d),
    )
    .unwrap();
    assert_eq!(out.shape, dq.shape);
    let events = events.into_inner().unwrap();
    let tiles = plan(&dq.shape, &tiled);
    assert_eq!(events.len(), tiles.len());
    let mut covered = vec![0u8; dq.shape.len()];
    for e in &events {
        assert_eq!((e.lo, e.size), (tiles[e.index].lo, tiles[e.index].size));
        for i in 0..e.size[0] {
            for j in 0..e.size[1] {
                for k in 0..e.size[2] {
                    covered[dq.shape.idx(e.lo[0] + i, e.lo[1] + j, e.lo[2] + k)] += 1;
                }
            }
        }
    }
    assert!(covered.iter().all(|&c| c == 1), "reported tiles must partition the field");
}
