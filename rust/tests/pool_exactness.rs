//! Pool-vs-sequential bit-exactness and steady-state spawn behavior.
//!
//! The persistent pool must be a pure throughput knob: for every
//! dataset, dimensionality (2D/3D, odd sizes, degenerate 1×N and
//! single-line grids) and thread count (including heavy oversubscription
//! — more threads than EDT lines), `mitigate` output must be
//! bit-identical to `threads = 1`. And after warm-up, a threaded
//! `mitigate()` call must spawn zero OS threads.
//!
//! NOTE: this binary deliberately creates no explicit `ThreadPool`s and
//! never calls `scope_blocking`, so `pool::os_thread_spawns()` can only
//! move when the global pool is first initialized — which the spawn
//! test forces before taking its baseline.

// The deprecated `mitigate` wrapper is exercised deliberately: it must
// stay bit-identical to the engine substrate it now wraps.
#![allow(deprecated)]

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::{mitigate, MitigationConfig};
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};
use qai::util::pool;

/// Thread counts swept everywhere: sequential, typical, odd, and
/// heavily oversubscribed (64 ≫ lines of any grid below).
const THREADS: [usize; 6] = [1, 2, 3, 4, 7, 64];

fn prepared(kind: DatasetKind, dims: &[usize], seed: u64) -> (Grid<f32>, Grid<QIndex>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (dq, q, eb)
}

fn assert_thread_invariant(kind: DatasetKind, dims: &[usize], seed: u64) {
    let (dq, q, eb) = prepared(kind, dims, seed);
    let seq = mitigate(&dq, &q, eb, &MitigationConfig { threads: 1, ..Default::default() });
    for threads in THREADS {
        let par = mitigate(&dq, &q, eb, &MitigationConfig { threads, ..Default::default() });
        assert_eq!(
            par.data, seq.data,
            "{kind:?} dims={dims:?} threads={threads}: pool output diverged from sequential"
        );
    }
}

#[test]
fn matrix_2d_odd_sizes() {
    assert_thread_invariant(DatasetKind::ClimateLike, &[33, 47], 11);
    assert_thread_invariant(DatasetKind::CosmologyLike, &[29, 31], 12);
}

#[test]
fn matrix_3d_odd_sizes() {
    assert_thread_invariant(DatasetKind::MirandaLike, &[17, 19, 23], 13);
    assert_thread_invariant(DatasetKind::CombustionLike, &[21, 13, 27], 14);
}

#[test]
fn matrix_3d_cubes() {
    assert_thread_invariant(DatasetKind::HurricaneLike, &[24, 24, 24], 15);
    assert_thread_invariant(DatasetKind::TurbulenceLike, &[16, 16, 16], 16);
}

#[test]
fn degenerate_single_line_1d() {
    // One EDT line total: every thread count > 1 is oversubscription.
    assert_thread_invariant(DatasetKind::ClimateLike, &[97], 17);
}

#[test]
fn degenerate_1xn_and_nx1_grids() {
    assert_thread_invariant(DatasetKind::ClimateLike, &[1, 64], 18);
    assert_thread_invariant(DatasetKind::ClimateLike, &[64, 1], 19);
    assert_thread_invariant(DatasetKind::MirandaLike, &[1, 1, 48], 20);
    assert_thread_invariant(DatasetKind::MirandaLike, &[1, 32, 32], 21);
}

#[test]
fn eta_and_taper_variants_also_thread_invariant() {
    let (dq, q, eb) = prepared(DatasetKind::CombustionLike, &[18, 22, 14], 22);
    for cfg_base in [
        MitigationConfig { eta: 0.5, ..Default::default() },
        MitigationConfig { taper_radius: Some(4.0), ..Default::default() },
    ] {
        let seq = mitigate(&dq, &q, eb, &MitigationConfig { threads: 1, ..cfg_base });
        for threads in THREADS {
            let par = mitigate(&dq, &q, eb, &MitigationConfig { threads, ..cfg_base });
            assert_eq!(par.data, seq.data, "cfg={cfg_base:?} threads={threads}");
        }
    }
}

#[test]
fn repeated_threaded_runs_are_identical() {
    // Schedule nondeterminism must never leak into outputs.
    let (dq, q, eb) = prepared(DatasetKind::TurbulenceLike, &[20, 20, 20], 23);
    let cfg = MitigationConfig { threads: 7, ..Default::default() };
    let first = mitigate(&dq, &q, eb, &cfg);
    for _ in 0..5 {
        assert_eq!(mitigate(&dq, &q, eb, &cfg).data, first.data);
    }
}

#[test]
fn warm_pool_mitigate_spawns_no_os_threads() {
    // Force global-pool initialization and run one throwaway threaded
    // region so the workers exist…
    let (dq, q, eb) = prepared(DatasetKind::MirandaLike, &[24, 24, 24], 24);
    let warm_cfg = MitigationConfig { threads: 4, ..Default::default() };
    let _ = mitigate(&dq, &q, eb, &warm_cfg);

    // …then every further threaded mitigation must spawn nothing.
    let before = pool::os_thread_spawns();
    for threads in [2usize, 4, 16, 64] {
        let cfg = MitigationConfig { threads, ..Default::default() };
        let _ = mitigate(&dq, &q, eb, &cfg);
    }
    assert_eq!(
        pool::os_thread_spawns(),
        before,
        "warm mitigate() must perform zero std::thread::spawn calls"
    );
}

#[test]
fn block_parallel_codecs_thread_invariant() {
    use qai::compressors::{sz3::Sz3Like, szp::SzpLike, Compressor};
    let orig = generate(DatasetKind::CosmologyLike, &[24, 24, 24], 25);
    let eb = ErrorBound::relative(1e-3).resolve(&orig.data);

    let stream = SzpLike::default().compress(&orig, eb).unwrap();
    let seq = SzpLike { threads: 1 }.decompress(&stream).unwrap();
    for threads in THREADS {
        let par = SzpLike { threads }.decompress(&stream).unwrap();
        assert_eq!(par.quant_indices.data, seq.quant_indices.data, "szp threads={threads}");
        assert_eq!(par.grid.data, seq.grid.data, "szp threads={threads}");
    }

    let stream = Sz3Like::default().compress(&orig, eb).unwrap();
    let seq = Sz3Like { threads: 1 }.decompress(&stream).unwrap();
    for threads in THREADS {
        let par = Sz3Like { threads }.decompress(&stream).unwrap();
        assert_eq!(par.data, seq.data, "sz3 threads={threads}");
    }
}
