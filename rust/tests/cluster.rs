//! Integration tests over the cluster subsystem: wire-codec round-trips
//! and malformed-input fuzzing, rendezvous-routing movement bounds, the
//! zero-copy local path, remote deadline shedding, 2-process
//! bit-identity over localhost TCP (the acceptance anchor), and real
//! multi-process rank meshes vs the in-process fabric.

use qai::cluster::node::{
    request_shutdown, ClusterEngine, ClusterError, ClusterServer, ClusterTransportStats,
};
use qai::cluster::procs::run_distributed_procs;
use qai::cluster::registry::NodeRegistry;
use qai::cluster::wire::{
    decode_message, encode_message, read_frame, write_frame, Handshake, Message, RankResult,
    RankSetup, RejectKind, RemoteOutcome, WireError, PROTOCOL_VERSION,
};
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::psnr;
use qai::mitigation::engine::{Engine, MitigationRequest, MitigationResponse, TransportStatsSource};
use qai::mitigation::pipeline::{mitigate, MitigationConfig};
use qai::mitigation::quality::QualityTarget;
use qai::mitigation::service::Job;
use qai::mitigation::tiled::TiledConfig;
use qai::mitigation::Priority;
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};
use qai::SharedGrid;
use std::io::{BufRead, BufReader, Cursor};
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

fn setup(dims: &[usize], seed: u64) -> (Grid<f32>, Grid<f32>, Grid<QIndex>, ResolvedBound) {
    let orig = generate(DatasetKind::MirandaLike, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (orig, dq, q, eb)
}

/// encode → decode → re-encode must reproduce the original bytes for
/// every message type (the decoded value carries everything the encoded
/// one did).
fn assert_reencodes(msg: &Message) {
    let bytes = encode_message(msg);
    let decoded = decode_message(&bytes)
        .unwrap_or_else(|e| panic!("decode failed for {msg:?}: {e}"));
    assert_eq!(encode_message(&decoded), bytes, "re-encode mismatch for {msg:?}");
}

// ---------------------------------------------------------------------
// Satellite: wire framing round-trips and malformed-input behavior
// ---------------------------------------------------------------------

#[test]
fn wire_roundtrip_all_message_types() {
    let (_orig, dq, q, eb) = setup(&[4, 4, 4], 1);

    assert_reencodes(&Message::Hello(Handshake { node_id: 42, version: PROTOCOL_VERSION }));
    assert_reencodes(&Message::Welcome {
        node_id: 7,
        version: PROTOCOL_VERSION,
        nodes: vec![7, 9, 11],
    });
    assert_reencodes(&Message::Shutdown);
    assert_reencodes(&Message::Tagged { tag: 1000, data: vec![1, 2, 3, 255] });
    assert_reencodes(&Message::Tagged { tag: 0, data: Vec::new() });
    assert_reencodes(&Message::RankHello { rank: 3, mesh_addr: "127.0.0.1:5555".into() });

    // Minimal request: every optional field absent.
    let bare = MitigationRequest::new(dq.clone(), q.clone(), eb);
    assert_reencodes(&Message::Request { req_id: 1, request: Box::new(bare) });

    // Maximal request: every optional field present.
    let job = Job {
        dq: SharedGrid::new(dq.clone()),
        q: SharedGrid::new(q.clone()),
        eb,
        cfg: MitigationConfig { eta: 0.7, threads: 2, ..Default::default() },
        reference: Some(SharedGrid::new(dq.clone())),
        target: Some(QualityTarget::Psnr(60.0)),
        tiled: Some(TiledConfig::new(&[4, 4]).with_halo(3)),
    };
    let full = MitigationRequest::from_job(job)
        .interactive()
        .deadline(Duration::from_millis(250))
        .tenant("alice");
    assert_reencodes(&Message::Request { req_id: u64::MAX, request: Box::new(full) });

    let resp = MitigationResponse {
        output: dq.clone(),
        stats: None,
        shard: Some(1),
        tenant: Some("alice".into()),
        seq: Some(3),
        trace_id: 77,
        priority: Priority::Interactive,
        queue_wait: Duration::from_micros(10),
        exec: Duration::from_millis(2),
        deadline: Some(Duration::from_millis(100)),
        deadline_missed: false,
        quality: Some(0.99),
    };
    assert_reencodes(&Message::Response { req_id: 9, outcome: Box::new(RemoteOutcome::Ok(resp)) });
    assert_reencodes(&Message::Response {
        req_id: 10,
        outcome: Box::new(RemoteOutcome::Rejected {
            kind: RejectKind::QuotaExceeded,
            message: "tenant at quota".into(),
        }),
    });

    let setup_msg = RankSetup {
        rank: 1,
        n_ranks: 2,
        strategy: Strategy::Approximate,
        eta: 0.9,
        threads: 1,
        eb,
        shape_dims: [1, 4, 16],
        shape_ndim: 2,
        dq: dq.clone(),
        q: q.clone(),
        mesh: vec!["127.0.0.1:1".into(), "127.0.0.1:2".into()],
    };
    assert_reencodes(&Message::RankSetup(Box::new(setup_msg)));
    assert_reencodes(&Message::RankResult(Box::new(RankResult {
        rank: 0,
        comm_nanos: 5,
        sent_bytes: 10,
        sent_msgs: 2,
        recv_bytes: 3,
        recv_msgs: 1,
        out: dq,
    })));
}

#[test]
fn wire_rejects_truncation_oversize_and_garbage() {
    // Clean EOF at a frame boundary.
    assert_eq!(read_frame(&mut Cursor::new(Vec::<u8>::new())), Err(WireError::Eof));

    // Torn length prefix.
    assert!(matches!(
        read_frame(&mut Cursor::new(vec![0x05u8, 0x00])),
        Err(WireError::Truncated { .. })
    ));

    // Torn body: a 5-byte frame cut off after the prefix + 2 bytes.
    let mut framed = Vec::new();
    write_frame(&mut framed, &[1, 2, 3, 4, 5]).unwrap();
    framed.truncate(6);
    assert!(matches!(
        read_frame(&mut Cursor::new(framed)),
        Err(WireError::Truncated { .. })
    ));

    // Oversized length prefix (0x41000001 > 1 GiB) is rejected before
    // any allocation — this must return instantly.
    assert!(matches!(
        read_frame(&mut Cursor::new(vec![0x01u8, 0x00, 0x00, 0x41])),
        Err(WireError::Oversized { .. })
    ));

    // Every strict prefix of a valid encoding fails with a typed error
    // (never panics, never succeeds): the decoder follows the same
    // byte-path as the full message until it runs off the end.
    let (_orig, dq, q, eb) = setup(&[4, 4, 4], 2);
    let msg = Message::Request {
        req_id: 3,
        request: Box::new(MitigationRequest::new(dq, q, eb).tenant("bob")),
    };
    let bytes = encode_message(&msg);
    for k in 0..bytes.len() {
        assert!(
            decode_message(&bytes[..k]).is_err(),
            "prefix of length {k}/{} decoded successfully",
            bytes.len()
        );
    }

    // Deterministic corruption fuzz: flip a few bytes anywhere in the
    // encoding; decode must return (Ok or typed Err), never panic.
    let mut x: u64 = 0x243F6A8885A308D3;
    let mut lcg = move || {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (x >> 33) as usize
    };
    for _round in 0..256 {
        let mut corrupt = bytes.clone();
        for _flip in 0..1 + lcg() % 4 {
            let at = lcg() % corrupt.len();
            corrupt[at] ^= (1 + lcg() % 255) as u8;
        }
        let _result = decode_message(&corrupt);
    }
}

#[test]
fn handshake_failures_are_typed() {
    let bytes = encode_message(&Message::Hello(Handshake {
        node_id: 7,
        version: PROTOCOL_VERSION,
    }));

    // Layout: tag(1) + magic(4) + version(4) + node_id(8).
    let mut bad_version = bytes.clone();
    bad_version[5] ^= 0xFF;
    match decode_message(&bad_version) {
        Err(WireError::VersionMismatch { ours, theirs }) => {
            assert_eq!(ours, PROTOCOL_VERSION);
            assert_ne!(theirs, PROTOCOL_VERSION);
        }
        other => panic!("expected VersionMismatch, got {other:?}"),
    }

    let mut bad_magic = bytes.clone();
    bad_magic[1] ^= 0xFF;
    assert!(matches!(decode_message(&bad_magic), Err(WireError::BadMagic(_))));

    let mut bad_tag = bytes.clone();
    bad_tag[0] = 99;
    assert_eq!(decode_message(&bad_tag).unwrap_err(), WireError::BadTag(99));

    let mut trailing = encode_message(&Message::Shutdown);
    trailing.push(0xAB);
    assert_eq!(decode_message(&trailing).unwrap_err(), WireError::TrailingBytes { extra: 1 });
}

// ---------------------------------------------------------------------
// Tentpole acceptance: rendezvous routing moves ≤ ⌈T/N⌉ tenants when a
// node joins
// ---------------------------------------------------------------------

#[test]
fn rendezvous_add_node_moves_at_most_ceil_t_over_n() {
    const T: usize = 100;
    let tenants: Vec<String> = (0..T).map(|i| format!("tenant-{i}")).collect();

    let mut reg = NodeRegistry::new(1);
    reg.add(2);
    reg.add(3);
    let before: Vec<u64> = tenants.iter().map(|t| reg.route(t).unwrap()).collect();

    assert!(reg.add(4));
    let n = reg.len(); // 4
    let after: Vec<u64> = tenants.iter().map(|t| reg.route(t).unwrap()).collect();

    let mut moved = 0usize;
    for ((tenant, &was), &now) in tenants.iter().zip(&before).zip(&after) {
        if was != now {
            moved += 1;
            // A tenant only ever moves *to* the new node — rendezvous
            // scores of existing nodes are unchanged by the join.
            assert_eq!(now, 4, "tenant {tenant} moved {was} -> {now}, not to the joiner");
        }
    }
    let bound = T.div_ceil(n); // ⌈T/N⌉ = 25
    assert!(moved <= bound, "{moved} tenants moved on join; bound is {bound}");
    assert!(moved > 0, "a 4th node that receives zero of 100 tenants means routing ignores it");

    // Routing is deterministic: same registry, same answers.
    let again: Vec<u64> = tenants.iter().map(|t| reg.route(t).unwrap()).collect();
    assert_eq!(after, again);
}

// ---------------------------------------------------------------------
// Local path: routing to the local node preserves SharedGrid zero-copy
// ---------------------------------------------------------------------

#[test]
fn local_route_is_zero_copy() {
    let (_orig, dq, q, eb) = setup(&[8, 8, 8], 3);
    let cluster = ClusterEngine::new(1, Arc::new(Engine::builder().shards(1).build()));

    let shared: SharedGrid<f32> = SharedGrid::new(dq);
    let shared_q: SharedGrid<QIndex> = SharedGrid::new(q);
    assert_eq!(shared.handle_count(), 1);

    // Pause dispatch so the job sits in the queue while we look at the
    // handle count.
    cluster.engine().pause();
    let ticket = cluster
        .submit(MitigationRequest::new(shared.clone(), shared_q.clone(), eb).tenant("solo"))
        .unwrap();
    assert!(!ticket.is_remote(), "single-node registry must route locally");
    assert_eq!(
        shared.handle_count(),
        2,
        "local submission must share the payload grid, not copy or serialize it"
    );
    cluster.engine().resume();
    let resp = ticket.wait().unwrap();
    assert_eq!(resp.output.shape, shared.shape);
    assert_eq!(resp.tenant.as_deref(), Some("solo"));
}

// ---------------------------------------------------------------------
// Satellite: deadlines cross the wire as remaining budget and shed on
// the remote node
// ---------------------------------------------------------------------

#[test]
fn remote_deadline_shed_regression() {
    let (_orig, dq, q, eb) = setup(&[16, 16, 16], 4);

    // Server node 202: sheds infeasible deadlines once its EWMA is warm.
    let server_engine = Arc::new(Engine::builder().shards(1).shed(true).build());
    let server_stats = ClusterTransportStats::new(202);
    server_engine.attach_transport(server_stats.clone());
    let mut server =
        ClusterServer::start(Arc::clone(&server_engine), 202, "127.0.0.1:0", server_stats)
            .unwrap();
    let addr = server.addr().to_string();

    // Client node 101 joins and picks a tenant that rendezvous-routes
    // to the server.
    let client = ClusterEngine::new(101, Arc::new(Engine::builder().shards(1).build()));
    assert_eq!(client.join(&addr).unwrap(), 202);
    assert_eq!(client.nodes(), vec![101, 202]);
    let mut reg = NodeRegistry::new(101);
    reg.add(202);
    let tenant = (0..64)
        .map(|i| format!("t{i}"))
        .find(|t| reg.route(t) == Some(202))
        .expect("64 tenants and none routes to the peer");

    // Warm the server's (tenant, shape) service-time estimate: the
    // estimate is recorded before the ticket resolves, so one completed
    // remote job is enough.
    let req = MitigationRequest::new(dq.clone(), q.clone(), eb).tenant(tenant.clone());
    let ticket = client.submit(req).unwrap();
    assert!(ticket.is_remote(), "tenant {tenant} was chosen to route remotely");
    let resp = ticket.wait().unwrap();
    assert_eq!(resp.output.shape, dq.shape);
    assert_eq!(resp.tenant.as_deref(), Some(tenant.as_str()));

    // A nearly-expired deadline: by the time the request is encoded the
    // remaining budget is ~zero nanoseconds. The wire carries that
    // budget (never an absolute instant); the server re-anchors it at
    // its own enqueue, projects with the warmed estimate, and sheds.
    let req = MitigationRequest::new(dq.clone(), q.clone(), eb)
        .tenant(tenant.clone())
        .deadline(Duration::from_nanos(1));
    let ticket = client.submit(req).unwrap();
    assert!(ticket.is_remote());
    match ticket.wait() {
        Err(ClusterError::Rejected { kind: RejectKind::DeadlineInfeasible, .. }) => {}
        other => panic!("expected remote DeadlineInfeasible shed, got {other:?}"),
    }

    // Satellite: both sides surface scope=transport metrics lines with
    // live byte counters.
    let client_metrics = client.engine().metrics_text();
    assert!(
        client_metrics.contains("scope=transport"),
        "client metrics missing transport scope:\n{client_metrics}"
    );
    assert!(
        server_engine.metrics_text().contains("scope=transport"),
        "server metrics missing transport scope"
    );
    let sent: u64 = client.transport_stats().transport_counters().iter().map(|c| c.sent_bytes).sum();
    let recv: u64 = client.transport_stats().transport_counters().iter().map(|c| c.recv_bytes).sum();
    assert!(sent > 0, "client sent two requests; sent_bytes must be nonzero");
    assert!(recv > 0, "client got a response; recv_bytes must be nonzero");

    server.stop();
}

// ---------------------------------------------------------------------
// Acceptance anchor: a 2-process engine (listener + joiner over
// localhost TCP) is bit-identical to a single-process engine for the
// same request set
// ---------------------------------------------------------------------

/// Kills the child on panic-unwind so a failed assertion doesn't leak a
/// listening process.
struct ChildGuard(Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _killed = self.0.kill();
        let _reaped = self.0.wait();
    }
}

#[test]
fn two_process_cluster_is_bit_identical_to_single_process() {
    let child = Command::new(env!("CARGO_BIN_EXE_qai"))
        .args(["serve", "--listen", "127.0.0.1:0", "--node-id", "202", "--shards", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn listener process");
    let mut guard = ChildGuard(child);
    let stdout = guard.0.stdout.take().expect("child stdout piped");
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("read listen line");
    let addr = line
        .trim()
        .split(" listening on ")
        .nth(1)
        .unwrap_or_else(|| panic!("unexpected listen line: {line:?}"))
        .to_string();
    assert!(line.starts_with("cluster node 202 "), "listen line: {line:?}");

    // Joiner node inside the test process.
    let local_engine = Arc::new(Engine::builder().shards(2).build());
    let cluster = ClusterEngine::new(101, Arc::clone(&local_engine));
    assert_eq!(cluster.join(&addr).unwrap(), 202);

    // Pick tenants so the request set provably exercises BOTH paths:
    // two that rendezvous-route locally, two that route to the peer.
    let mut reg = NodeRegistry::new(101);
    reg.add(202);
    let mut locals = Vec::new();
    let mut remotes = Vec::new();
    for i in 0..64 {
        let t = format!("t{i}");
        match reg.route(&t) {
            Some(101) => locals.push(t),
            Some(202) => remotes.push(t),
            other => panic!("route returned unknown node {other:?}"),
        }
    }
    assert!(locals.len() >= 2 && remotes.len() >= 2, "pathological rendezvous split");
    let tenants =
        [locals[0].clone(), remotes[0].clone(), locals[1].clone(), remotes[1].clone()];

    // Same request set, three executions: cluster (mixed local/remote),
    // and a plain single-process engine as the reference.
    let jobs: Vec<(Grid<f32>, Grid<QIndex>, ResolvedBound)> = (0..8)
        .map(|i| {
            let (_orig, dq, q, eb) = setup(&[12, 12, 12], 100 + i);
            (dq, q, eb)
        })
        .collect();

    let reference = Arc::new(Engine::builder().shards(2).build());
    let mut expected = Vec::new();
    for (i, (dq, q, eb)) in jobs.iter().enumerate() {
        let req = MitigationRequest::new(dq.clone(), q.clone(), *eb)
            .tenant(tenants[i % tenants.len()].clone());
        expected.push(reference.submit(req).unwrap().wait().unwrap().output);
    }

    let mut tickets = Vec::new();
    for (i, (dq, q, eb)) in jobs.iter().enumerate() {
        let tenant = tenants[i % tenants.len()].clone();
        let expect_remote = reg.route(&tenant) == Some(202);
        let ticket = cluster
            .submit(MitigationRequest::new(dq.clone(), q.clone(), *eb).tenant(tenant))
            .unwrap();
        assert_eq!(
            ticket.is_remote(),
            expect_remote,
            "job {i}: observed path disagrees with rendezvous routing"
        );
        tickets.push(ticket);
    }
    let mut saw_remote = false;
    let mut saw_local = false;
    for (i, ticket) in tickets.into_iter().enumerate() {
        saw_remote |= ticket.is_remote();
        saw_local |= !ticket.is_remote();
        let resp = ticket.wait().unwrap();
        assert_eq!(
            resp.output.data, expected[i].data,
            "job {i}: cluster output differs from single-process output"
        );
    }
    assert!(saw_remote && saw_local, "request set must cross the wire AND stay home");

    // Clean shutdown: the listener must exit 0.
    request_shutdown(&addr, 101).unwrap();
    let status = guard.0.wait().expect("wait for listener exit");
    assert!(status.success(), "listener exited with {status:?}");
}

// ---------------------------------------------------------------------
// Real multi-process rank meshes (fig9/fig11 infrastructure) match the
// in-process fabric bit-for-bit
// ---------------------------------------------------------------------

#[test]
fn multi_process_ranks_match_in_process_distributed() {
    let qai_bin = Path::new(env!("CARGO_BIN_EXE_qai"));
    let (orig, dq, q, eb) = setup(&[16, 16, 16], 5);

    // Approximate: halo exchanges over real sockets.
    let cfg = DistributedConfig { ranks: 2, strategy: Strategy::Approximate, eta: 0.9, ..Default::default() };
    let (in_proc, _rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
    let (out, report) =
        run_distributed_procs(qai_bin, &dq, &q, eb, Strategy::Approximate, 2, 0.9, 1).unwrap();
    assert_eq!(out.data, in_proc.data, "approximate: sockets vs fabric outputs differ");
    assert_eq!(report.ranks, 2);
    assert!(report.bytes > 0, "halo exchange must move bytes over the mesh");
    assert!(report.msgs > 0);
    assert!(report.wall_s > 0.0);

    // Exact: exercises the gather/scatter path including the leader's
    // self-send, and must remain sequential-identical.
    let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
    let (out, _report) =
        run_distributed_procs(qai_bin, &dq, &q, eb, Strategy::Exact, 2, 0.9, 1).unwrap();
    assert_eq!(out.data, seq.data, "exact: multi-process output must be sequential-identical");
    assert!(psnr(&orig.data, &out.data) > psnr(&orig.data, &dq.data));
}
