//! End-to-end integration: compressor → decompress → mitigate → metrics,
//! across codecs and datasets — the full user-facing flow of the repo.

// The deprecated `mitigate` wrapper is exercised deliberately: the
// end-to-end flow must hold through the legacy entry point too.
#![allow(deprecated)]

use qai::compressors::{cusz::CuszLike, cuszp::CuszpLike, szp::SzpLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::filters::{gaussian_filter, uniform_filter, wiener_filter};
use qai::metrics::{max_abs_error, max_rel_error, psnr, ssim};
use qai::mitigation::{mitigate, MitigationConfig};
use qai::quant::ErrorBound;

fn codecs() -> Vec<Box<dyn Compressor>> {
    vec![Box::new(CuszLike), Box::new(CuszpLike), Box::new(SzpLike { threads: 2 })]
}

#[test]
fn every_codec_roundtrips_and_mitigation_improves_quality() {
    let orig = generate(DatasetKind::MirandaLike, &[40, 40, 40], 2026);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    for codec in codecs() {
        let stream = codec.compress(&orig, eb).unwrap();
        let dec = codec.decompress(&stream).unwrap();
        assert!(max_abs_error(&orig.data, &dec.grid.data) <= eb.abs * (1.0 + 1e-9));

        let fixed = mitigate(&dec.grid, &dec.quant_indices, dec.bound, &MitigationConfig::default());
        let p0 = psnr(&orig.data, &dec.grid.data);
        let p1 = psnr(&orig.data, &fixed.data);
        let s0 = ssim(&orig, &dec.grid, 7, 2);
        let s1 = ssim(&orig, &fixed, 7, 2);
        assert!(p1 > p0, "{}: PSNR {p0:.2} -> {p1:.2}", codec.name());
        assert!(s1 > s0, "{}: SSIM {s0:.4} -> {s1:.4}", codec.name());
        // relaxed bound guaranteed
        assert!(max_abs_error(&orig.data, &fixed.data) <= 1.9 * eb.abs * (1.0 + 1e-5));
    }
}

#[test]
fn identical_quant_indices_across_prequant_codecs() {
    // Pre-quantization decouples the index field from the pipeline: all
    // three codecs must reconstruct the *same* indices.
    let orig = generate(DatasetKind::HurricaneLike, &[24, 24, 24], 99);
    let eb = ErrorBound::relative(1e-3).resolve(&orig.data);
    let reference = CuszLike.decompress(&CuszLike.compress(&orig, eb).unwrap()).unwrap();
    for codec in codecs() {
        let dec = codec.decompress(&codec.compress(&orig, eb).unwrap()).unwrap();
        assert_eq!(
            dec.quant_indices.data, reference.quant_indices.data,
            "{} diverged from cuSZ-like indices",
            codec.name()
        );
    }
}

#[test]
fn table2_shape_ours_bounded_filters_not() {
    // Table II's headline: the compensation respects the relaxed bound
    // (1+η)ε while Gaussian/uniform filters can blow past it near fronts.
    let orig = generate(DatasetKind::CombustionLike, &[48, 48, 48], 17);
    let rel = 1e-3;
    let eb = ErrorBound::relative(rel).resolve(&orig.data);
    let dec = CuszLike.decompress(&CuszLike.compress(&orig, eb).unwrap()).unwrap();

    let ours = mitigate(&dec.grid, &dec.quant_indices, eb, &MitigationConfig::default());
    let relaxed = (1.0 + 0.9) * rel;
    assert!(max_rel_error(&orig.data, &ours.data) <= relaxed * (1.0 + 1e-5));

    let gauss = gaussian_filter(&dec.grid, 1.0);
    let unif = uniform_filter(&dec.grid);
    let wien = wiener_filter(&dec.grid, eb.abs);
    // The sharp flame front guarantees the smoothers break the bound.
    assert!(max_rel_error(&orig.data, &gauss.data) > relaxed);
    assert!(max_rel_error(&orig.data, &unif.data) > relaxed);
    // Wiener is the best-behaved baseline but still has no guarantee;
    // just check it produced something finite.
    assert!(wien.data.iter().all(|v| v.is_finite()));
}

#[test]
fn mitigation_is_deterministic() {
    let orig = generate(DatasetKind::CosmologyLike, &[32, 32, 32], 4);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let dec = CuszpLike.decompress(&CuszpLike.compress(&orig, eb).unwrap()).unwrap();
    let a = mitigate(&dec.grid, &dec.quant_indices, eb, &MitigationConfig::default());
    let b = mitigate(&dec.grid, &dec.quant_indices, eb, &MitigationConfig::default());
    assert_eq!(a.data, b.data);
}
