//! Integration tests for the AOT (JAX/Pallas → HLO text) → PJRT path:
//! the PJRT backend must agree with the native Rust implementation.
//!
//! Requires `make artifacts`; each test skips (with a loud message) if
//! the artifacts are missing so that a fresh checkout still passes
//! `cargo test` before its first `make artifacts`.

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::max_abs_error;
use qai::mitigation::boundary::boundary_and_sign;
use qai::mitigation::edt::{edt, INF};
use qai::mitigation::interpolate::compensate;
use qai::mitigation::pipeline::{mitigate_with_stats, Backend, MitigationConfig};
use qai::quant::{quantize_grid, ErrorBound};
use qai::runtime::ops;
use qai::util::rng::Rng;

fn artifacts_present() -> bool {
    let dir = std::env::var("QAI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let ok = std::path::Path::new(&dir).join("manifest.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

#[test]
fn idw_kernel_matches_native_compensate() {
    if !artifacts_present() {
        return;
    }
    let n = 100_000; // exercises chunking incl. a partial tail chunk
    let mut rng = Rng::new(7);
    let mut data_native: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let d1: Vec<i64> = (0..n)
        .map(|i| match i % 5 {
            0 => 0,
            1 => INF,
            _ => ((i * 13) % 97 + 1) as i64,
        })
        .collect();
    let d2: Vec<i64> = (0..n)
        .map(|i| match i % 7 {
            0 => 0,
            1 => INF,
            _ => ((i * 29) % 83 + 1) as i64,
        })
        .collect();
    let sign: Vec<i8> = (0..n).map(|i| [(-1i8), 0, 1][i % 3]).collect();
    let eta_eps = 0.0123f64;

    let mut data_pjrt = data_native.clone();
    compensate(&mut data_native, &d1, &d2, &sign, eta_eps, 1);
    ops::compensate_pjrt(&mut data_pjrt, &d1, &d2, &sign, eta_eps).unwrap();

    let max_dev = data_native
        .iter()
        .zip(&data_pjrt)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_dev < 1e-6, "native vs pjrt max dev {max_dev}");
}

#[test]
fn boundary_kernel_matches_native_3d() {
    if !artifacts_present() {
        return;
    }
    // 70³ exercises multi-tile + partial-tile paths of the 64³ stencil.
    let orig = generate(DatasetKind::MirandaLike, &[70, 70, 70], 5);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, _) = quantize_grid(&orig, eb);
    let native = boundary_and_sign(&q, 1);
    let pjrt = ops::boundary_and_sign_pjrt(&q).unwrap();
    assert_eq!(native.mask.data, pjrt.mask.data, "mask mismatch");
    assert_eq!(native.sign.data, pjrt.sign.data, "sign mismatch");
}

#[test]
fn boundary_kernel_matches_native_2d() {
    if !artifacts_present() {
        return;
    }
    // 300² exercises multi-tile 2D (256 + partial).
    let orig = generate(DatasetKind::ClimateLike, &[300, 300], 9);
    let eb = ErrorBound::relative(5e-3).resolve(&orig.data);
    let (q, _) = quantize_grid(&orig, eb);
    let native = boundary_and_sign(&q, 1);
    let pjrt = ops::boundary_and_sign_pjrt(&q).unwrap();
    assert_eq!(native.mask.data, pjrt.mask.data);
    assert_eq!(native.sign.data, pjrt.sign.data);
}

#[test]
fn full_pipeline_pjrt_matches_native() {
    if !artifacts_present() {
        return;
    }
    let orig = generate(DatasetKind::CombustionLike, &[48, 48, 48], 11);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let native_cfg = MitigationConfig { backend: Backend::Native, ..Default::default() };
    let pjrt_cfg = MitigationConfig { backend: Backend::Pjrt, ..Default::default() };
    let (out_native, _) = mitigate_with_stats(&dq, &q, eb, &native_cfg).unwrap();
    let (out_pjrt, _) = mitigate_with_stats(&dq, &q, eb, &pjrt_cfg).unwrap();
    let dev = max_abs_error(&out_native.data, &out_pjrt.data);
    assert!(dev < 1e-6 * eb.abs.max(1.0), "pipeline dev {dev}");
    // and still within the relaxed bound vs the original
    let bound = (1.0 + 0.9) * eb.abs;
    assert!(max_abs_error(&orig.data, &out_pjrt.data) <= bound * (1.0 + 1e-5));
}

#[test]
fn prequant_kernel_respects_error_bound() {
    if !artifacts_present() {
        return;
    }
    let mut rng = Rng::new(21);
    let data: Vec<f32> = (0..70_000).map(|_| rng.f32() * 10.0 - 5.0).collect();
    let eps = 0.05f64;
    let (q, dq) = ops::prequant_pjrt(&data, eps).unwrap();
    assert_eq!(q.len(), data.len());
    for (d, r) in data.iter().zip(&dq) {
        assert!(((d - r) as f64).abs() <= eps * (1.0 + 1e-5), "d={d} r={r}");
    }
    // XLA rounds half-to-even; away from ties it must agree with native.
    let native_eb = qai::quant::ResolvedBound { abs: eps, rel: None };
    let native_q = qai::quant::quantize(&data, native_eb);
    let disagreements = q
        .iter()
        .zip(&native_q)
        .filter(|(&a, &b)| a as i64 != b)
        .count();
    assert!(
        disagreements < data.len() / 1000,
        "too many rounding disagreements: {disagreements}"
    );
}

#[test]
fn pjrt_backend_rejects_1d_grids() {
    if !artifacts_present() {
        return;
    }
    let q = Grid::from_vec(vec![0i64, 0, 1, 1], &[4]);
    assert!(ops::boundary_and_sign_pjrt(&q).is_err());
}
