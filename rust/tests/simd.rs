//! SIMD substrate bit-exactness matrix (ISSUE 10 acceptance test).
//!
//! Every vectorized hot kernel must be bit-identical to its scalar
//! twin on real pipeline data — across datasets, 2D/3D odd and
//! lane-multiple dims, the forced-scalar level versus the detected
//! level, and thread counts. The `*_with(level)` entry points make the
//! comparison direct: `SimdLevel::Scalar` is the semantic reference,
//! `simd::level()` is whatever dispatch picked for this machine (under
//! `QAI_SIMD=scalar` both sides are scalar and the matrix degenerates
//! to a self-check, which is exactly the CI forced-scalar pass).

use qai::compressors::{bitio, huffman, lorenzo};
use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::filters::gaussian::gaussian_filter_threads;
use qai::metrics::ssim_fast_threads;
use qai::mitigation::boundary::boundary_and_sign;
use qai::mitigation::edt::{edt, INF};
use qai::mitigation::sign::propagate_signs;
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};
use qai::util::pool::ThreadPool;
use qai::util::simd::{self, SimdLevel};

/// The dataset × dims matrix: 2D and 3D, odd sizes (every row ends in
/// a vector tail) and exact lane multiples (no tail at all).
const CASES: [(DatasetKind, &[usize], u64); 4] = [
    (DatasetKind::ClimateLike, &[33, 47], 11),
    (DatasetKind::CosmologyLike, &[29, 31], 12),
    (DatasetKind::MirandaLike, &[17, 19, 23], 13),
    (DatasetKind::CombustionLike, &[16, 16, 16], 14),
];

fn prepared(
    kind: DatasetKind,
    dims: &[usize],
    seed: u64,
) -> (Grid<f32>, Grid<QIndex>, Grid<f32>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (orig, q, dq, eb)
}

fn assert_f32_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit divergence at {i}: {x} vs {y}");
    }
}

fn assert_f64_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit divergence at {i}: {x} vs {y}");
    }
}

#[test]
fn quantize_and_dequantize_match_scalar_twin() {
    let level = simd::level();
    for (kind, dims, seed) in CASES {
        let (orig, q, _dq, eb) = prepared(kind, dims, seed);
        let inv = 1.0 / (2.0 * eb.abs);
        let n = orig.data.len();

        let mut qs = vec![0i64; n];
        let mut qv = vec![0i64; n];
        simd::quantize_with(SimdLevel::Scalar, &orig.data, inv, &mut qs);
        simd::quantize_with(level, &orig.data, inv, &mut qv);
        assert_eq!(qs, qv, "{kind:?} dims={dims:?}: quantize diverged");

        let mut fs = vec![0f32; n];
        let mut fv = vec![0f32; n];
        simd::dequantize_into_with(SimdLevel::Scalar, &q.data, 2.0 * eb.abs, &mut fs);
        simd::dequantize_into_with(level, &q.data, 2.0 * eb.abs, &mut fv);
        assert_f32_bits_eq(&fs, &fv, "dequantize");
    }
}

#[test]
fn lorenzo_forward_inverse_match_scalar_and_roundtrip() {
    let level = simd::level();
    for (kind, dims, seed) in CASES {
        let (_orig, q, _dq, _eb) = prepared(kind, dims, seed);
        let rs = lorenzo::forward_with(SimdLevel::Scalar, &q);
        let rv = lorenzo::forward_with(level, &q);
        assert_eq!(rs, rv, "{kind:?} dims={dims:?}: lorenzo forward diverged");

        let gs = lorenzo::inverse_with(SimdLevel::Scalar, &rs, q.shape);
        let gv = lorenzo::inverse_with(level, &rs, q.shape);
        assert_eq!(gs.data, gv.data, "{kind:?} dims={dims:?}: lorenzo inverse diverged");
        assert_eq!(gv.data, q.data, "{kind:?} dims={dims:?}: lorenzo roundtrip broke");
    }
}

#[test]
fn compensate_matches_scalar_on_real_distance_fields() {
    let level = simd::level();
    for (kind, dims, seed) in CASES {
        let (_orig, q, dq, eb) = prepared(kind, dims, seed);
        let bres = boundary_and_sign(&q, 1);
        let e1 = edt(&bres.mask, true, 1);
        let nearest = e1.nearest.as_ref().unwrap();
        let (s, b2) = propagate_signs(&bres.mask, &bres.sign, nearest, 1);
        let e2 = edt(&b2, false, 1);

        let mut a = dq.data.clone();
        let mut b = dq.data.clone();
        let eta_eps = 0.9 * eb.abs;
        let scalar = SimdLevel::Scalar;
        simd::compensate_with(scalar, &mut a, &e1.dist_sq, &e2.dist_sq, &s.data, eta_eps, INF);
        simd::compensate_with(level, &mut b, &e1.dist_sq, &e2.dist_sq, &s.data, eta_eps, INF);
        assert_f32_bits_eq(&a, &b, "compensate");
    }
}

#[test]
fn convolve_and_ssim_moments_match_scalar() {
    let level = simd::level();
    for (kind, dims, seed) in CASES {
        let (orig, _q, dq, _eb) = prepared(kind, dims, seed);
        let n = orig.data.len();

        for radius in [1usize, 2, 4] {
            let kernel = qai::filters::gaussian::gaussian_kernel(0.8 * radius as f64, radius);
            let line: Vec<f64> = dq.data.iter().map(|&v| v as f64).collect();
            let m = n - (kernel.len() - 1);
            let mut os = vec![0f64; m];
            let mut ov = vec![0f64; m];
            simd::convolve_valid_with(SimdLevel::Scalar, &mut os, &line, &kernel);
            simd::convolve_valid_with(level, &mut ov, &line, &kernel);
            assert_f64_bits_eq(&os, &ov, "convolve_valid");
        }

        let (lof, inv) = (0.25f64, 1.0 / 127.0f64);
        let moments = |lvl: SimdLevel| {
            let mut sx = vec![0f64; n];
            let mut sy = vec![0f64; n];
            let mut sxx = vec![0f64; n];
            let mut syy = vec![0f64; n];
            let mut sxy = vec![0f64; n];
            simd::ssim_moments_with(
                lvl,
                &orig.data,
                &dq.data,
                lof,
                inv,
                &mut sx,
                &mut sy,
                &mut sxx,
                &mut syy,
                &mut sxy,
            );
            [sx, sy, sxx, syy, sxy]
        };
        let ms = moments(SimdLevel::Scalar);
        let mv = moments(level);
        for (i, (a, b)) in ms.iter().zip(&mv).enumerate() {
            assert_f64_bits_eq(a, b, &format!("ssim moment {i}"));
        }
    }
}

#[test]
fn huffman_table_decode_matches_bit_serial_on_real_residuals() {
    for (kind, dims, seed) in CASES {
        let (_orig, q, _dq, _eb) = prepared(kind, dims, seed);
        let residuals = lorenzo::forward_with(SimdLevel::Scalar, &q);
        let symbols: Vec<u32> =
            residuals.iter().map(|&r| bitio::zigzag(r).min(u32::MAX as u64) as u32).collect();
        let buf = huffman::encode(&symbols);
        let mut slow = vec![0u32; symbols.len()];
        let mut fast = vec![0u32; symbols.len()];
        huffman::decode_into_with(&buf, &mut slow, false).unwrap();
        huffman::decode_into_with(&buf, &mut fast, true).unwrap();
        assert_eq!(slow, symbols, "{kind:?}: bit-serial decode broke");
        assert_eq!(fast, symbols, "{kind:?}: table decode diverged");
    }
}

/// Threaded public entry points stay bit-identical to `threads = 1`
/// under whatever SIMD level dispatch picked (the pool splits work at
/// line/batch granularity, never mid-vector, so lane boundaries and
/// thread boundaries must not interact).
#[test]
fn threaded_paths_are_thread_invariant_under_simd() {
    for (kind, dims, seed) in CASES {
        let (orig, _q, dq, _eb) = prepared(kind, dims, seed);

        let s1 = ssim_fast_threads(&orig, &dq, 7, 2, 1);
        let g1 = gaussian_filter_threads(&dq, 1.1, 1);
        for threads in [2usize, 4] {
            let st = ssim_fast_threads(&orig, &dq, 7, 2, threads);
            assert_eq!(s1.to_bits(), st.to_bits(), "{kind:?} threads={threads}: ssim diverged");
            let gt = gaussian_filter_threads(&dq, 1.1, threads);
            assert_f32_bits_eq(&g1.data, &gt.data, "gaussian_filter");
        }
    }
}

#[test]
fn forced_levels_clamp_to_hardware() {
    // Asking a `*_with` entry point for a level the CPU lacks must not
    // fault: the kernels clamp to `best_supported()` internally, so
    // every level token is safe to request on every machine.
    let data = [1.0f32, -2.5, 3.25, 0.0, 9.75, -0.5, 2.0];
    for level in [SimdLevel::Scalar, SimdLevel::Sse2, SimdLevel::Avx2] {
        let mut out = vec![0i64; data.len()];
        simd::quantize_with(level, &data, 0.5, &mut out);
        let mut back = vec![0f32; data.len()];
        simd::dequantize_into_with(level, &out, 2.0, &mut back);
    }
}

#[test]
fn pinned_pool_reports_worker_cpus() {
    // 4 lanes = 3 persistent workers (the caller is the 4th lane).
    let pool = ThreadPool::with_pinning(4, true);
    let cpus = pool.worker_cpus();
    assert_eq!(cpus.len(), pool.workers());
    assert_eq!(cpus.len(), 3);
    #[cfg(target_os = "linux")]
    {
        // Workers record their observed CPU at startup; give them a
        // moment, then every slot must hold a real CPU id.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let cpus = pool.worker_cpus();
            if cpus.iter().all(|&c| c >= 0) {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "worker CPUs never reported: {cpus:?}");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
    }
}

#[test]
fn engine_builder_pin_workers_smoke() {
    let engine = qai::mitigation::engine::Engine::builder()
        .shards(2)
        .lanes_per_shard(2)
        .pin_workers(false)
        .build();
    assert_eq!(engine.shards(), 2);
}
