//! Pool-confinement proof: a job submitted to a
//! `MitigationService::with_pool` service runs its *internal* steps
//! A–E only on that pool.
//!
//! This file is its own test binary (= its own process) on purpose: the
//! strongest observable is that the **global pool is never created**.
//! `pool::global_is_initialized()` flips the moment anything falls back
//! to the global pool, so every assertion here would catch a single
//! stray call site. Do not add tests to this binary that touch the
//! global pool.

// Legacy wrappers (`mitigate`, the service constructors) are exercised
// deliberately alongside the engine path: confinement must hold on
// both.
#![allow(deprecated)]

use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{Engine, MitigationRequest};
use qai::mitigation::{
    mitigate, Job, MitigationConfig, MitigationService, ServiceConfig, SubmitOptions,
};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::pool::{self, ThreadPool};
use std::sync::Arc;

#[test]
fn private_pool_job_runs_internal_steps_only_on_that_pool() {
    let orig = generate(DatasetKind::MirandaLike, &[32, 32, 32], 11);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);

    // Expected output from the sequential path, which runs inline and
    // touches no pool at all (so the probe below is still meaningful).
    let expected = mitigate(&dq, &q, eb, &MitigationConfig { threads: 1, ..Default::default() });
    assert!(
        !pool::global_is_initialized(),
        "threads == 1 mitigation must not create the global pool"
    );

    // A 4-lane private pool carries the whole service: admission
    // fan-out AND the job's internal steps at threads = 4.
    let private = Arc::new(ThreadPool::new(4));
    let regions_before = private.regions_opened();
    let service = MitigationService::with_config(ServiceConfig {
        pool: Some(private.clone()),
        capacity: 4,
        start_paused: false,
        ..Default::default()
    });
    let job = Job::with_config(dq, q, eb, MitigationConfig { threads: 4, ..Default::default() });
    let report = service.submit(job, SubmitOptions::interactive()).unwrap().wait();
    let (out, stats) = report.result.expect("confined job must succeed");

    // Bit-identical to the sequential reference…
    assert_eq!(out.data, expected.data, "pool confinement must not change outputs");
    assert!(stats.n_boundary1 > 0, "test field must actually exercise the pipeline");
    // …with the parallel steps demonstrably on the private pool…
    assert!(
        private.regions_opened() > regions_before,
        "threads = 4 steps must open parallel regions on the private pool"
    );
    // …and nothing on the global one.
    assert!(
        !pool::global_is_initialized(),
        "no step of a pool-confined job may fall back to the global pool"
    );

    // A second batch through the compatibility wrapper stays confined
    // too (homogeneous index grid: cheap identity job).
    let job2 = Job::with_config(
        expected.clone(),
        qai::Grid::<i64>::like(&expected),
        eb,
        MitigationConfig { threads: 2, ..Default::default() },
    );
    let results = service.mitigate_batch(std::slice::from_ref(&job2));
    assert!(results[0].is_ok());
    assert!(!pool::global_is_initialized(), "mitigate_batch must stay confined as well");
}

#[test]
fn sharded_engine_with_explicit_pool_stays_confined() {
    let orig = generate(DatasetKind::CombustionLike, &[24, 24, 24], 5);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);

    let private = Arc::new(ThreadPool::new(3));
    let regions_before = private.regions_opened();
    let engine = Engine::builder().shards(2).pool(private.clone()).shared_arena(true).build();
    let request = MitigationRequest::new(dq, q, eb)
        .config(MitigationConfig { threads: 3, ..Default::default() })
        .tenant("confined");
    let response = engine.run(request).expect("confined engine job must succeed");
    assert!(response.output.len() == 24 * 24 * 24);
    assert!(
        private.regions_opened() > regions_before,
        "threads = 3 steps must open parallel regions on the engine's pool"
    );
    assert!(
        !pool::global_is_initialized(),
        "no step of a pool-confined engine job may fall back to the global pool"
    );
}
