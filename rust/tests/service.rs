//! `MitigationService::mitigate_batch` integration tests: exactness vs
//! per-field calls, per-job error isolation, determinism of concurrent
//! batches on the shared pool, and explicit-pool operation.

// The deprecated constructors/batch wrappers are exercised
// deliberately: this suite pins the legacy batch path, now a thin
// wrapper over `Engine::run_batch` (see rust/tests/engine.rs for the
// typed front door).
#![allow(deprecated)]

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::{mitigate_with_stats, Job, MitigationConfig, MitigationService};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::pool::ThreadPool;
use std::sync::Arc;

fn make_job(kind: DatasetKind, dims: &[usize], seed: u64, threads: usize) -> Job {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    Job::with_config(dq, q, eb, MitigationConfig { threads, ..Default::default() })
}

fn mixed_batch() -> Vec<Job> {
    vec![
        make_job(DatasetKind::ClimateLike, &[48, 48], 1, 1),
        make_job(DatasetKind::MirandaLike, &[20, 20, 20], 2, 2),
        make_job(DatasetKind::CombustionLike, &[16, 24, 18], 3, 4),
        make_job(DatasetKind::HurricaneLike, &[22, 22, 22], 4, 1),
        make_job(DatasetKind::ClimateLike, &[33, 47], 5, 3),
        make_job(DatasetKind::TurbulenceLike, &[14, 14, 14], 6, 2),
    ]
}

#[test]
fn batch_matches_per_field_mitigate_exactly() {
    let jobs = mixed_batch();
    let service = MitigationService::new();
    let results = service.mitigate_batch(&jobs);
    assert_eq!(results.len(), jobs.len());
    for (i, (job, result)) in jobs.iter().zip(&results).enumerate() {
        let (batch_out, batch_stats) = result.as_ref().expect("job must succeed");
        let (solo_out, solo_stats) = mitigate_with_stats(&job.dq, &job.q, job.eb, &job.cfg).unwrap();
        assert_eq!(batch_out.data, solo_out.data, "job {i}: output diverged");
        assert_eq!(batch_stats.n_boundary1, solo_stats.n_boundary1, "job {i}");
        assert_eq!(batch_stats.n_boundary2, solo_stats.n_boundary2, "job {i}");
    }
}

#[test]
fn per_job_errors_do_not_poison_the_batch() {
    let mut jobs = mixed_batch();
    // Poison job 2 with a shape mismatch between data and indices.
    jobs[2].q = Grid::from_vec(vec![0i64; 8], &[2, 4]).into();
    let service = MitigationService::new();
    let results = service.mitigate_batch(&jobs);
    for (i, result) in results.iter().enumerate() {
        if i == 2 {
            let msg = result.as_ref().unwrap_err().to_string();
            assert!(msg.contains("shape"), "job 2 error should mention shape: {msg}");
        } else {
            let (out, _) = result.as_ref().expect("healthy jobs must still succeed");
            let (solo, _) =
                mitigate_with_stats(&jobs[i].dq, &jobs[i].q, jobs[i].eb, &jobs[i].cfg).unwrap();
            assert_eq!(out.data, solo.data, "job {i} corrupted by sibling failure");
        }
    }
}

#[test]
fn concurrent_batches_on_shared_pool_are_deterministic() {
    let jobs = mixed_batch();
    let reference: Vec<Vec<f32>> = MitigationService::new()
        .mitigate_batch(&jobs)
        .into_iter()
        .map(|r| r.unwrap().0.data)
        .collect();

    // Several client threads hammer the same global pool with the same
    // batch concurrently; every client must see identical outputs.
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let jobs = &jobs;
                let reference = &reference;
                s.spawn(move || {
                    let service = MitigationService::new();
                    for round in 0..3 {
                        let got = service.mitigate_batch(jobs);
                        for (i, r) in got.into_iter().enumerate() {
                            let (out, _) = r.unwrap();
                            assert_eq!(
                                out.data, reference[i],
                                "round {round} job {i}: nondeterministic batch output"
                            );
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
}

#[test]
fn explicit_pool_matches_global_pool() {
    let jobs = mixed_batch();
    let global_results = MitigationService::new().mitigate_batch(&jobs);
    for lanes in [1usize, 2, 5] {
        let service = MitigationService::with_pool(Arc::new(ThreadPool::new(lanes)));
        let results = service.mitigate_batch(&jobs);
        for (i, (a, b)) in global_results.iter().zip(&results).enumerate() {
            assert_eq!(
                a.as_ref().unwrap().0.data,
                b.as_ref().unwrap().0.data,
                "lanes={lanes} job {i}"
            );
        }
    }
}

#[test]
fn batch_of_one_and_empty_batch() {
    let service = MitigationService::new();
    assert!(service.mitigate_batch(&[]).is_empty());
    let jobs = vec![make_job(DatasetKind::CosmologyLike, &[12, 12, 12], 7, 2)];
    let results = service.mitigate_batch(&jobs);
    assert_eq!(results.len(), 1);
    assert!(results[0].is_ok());
}

#[test]
fn homogeneous_job_is_identity_inside_a_batch() {
    let dq = Grid::from_vec(vec![2.5f32; 125], &[5, 5, 5]);
    let q = Grid::from_vec(vec![3i64; 125], &[5, 5, 5]);
    let eb = ErrorBound::absolute(0.1).resolve(&dq.data);
    let jobs = vec![Job::new(dq.clone(), q, eb), make_job(DatasetKind::ClimateLike, &[24, 24], 8, 2)];
    let results = MitigationService::new().mitigate_batch(&jobs);
    let (out, stats) = results[0].as_ref().unwrap();
    assert_eq!(out.data, dq.data);
    assert_eq!(stats.n_boundary1, 0);
    assert!(results[1].is_ok());
}
