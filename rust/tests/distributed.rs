//! Integration tests over the distributed coordinator: strategy quality
//! ordering (the paper's Fig. 4 story), scaling-report sanity and
//! failure-injection on the fabric protocol.

use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::mitigation::pipeline::{mitigate, MitigationConfig};
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};

fn setup(
    kind: DatasetKind,
    dims: &[usize],
    rel: f64,
    seed: u64,
) -> (Grid<f32>, Grid<f32>, Grid<QIndex>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(rel).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (orig, dq, q, eb)
}

#[test]
fn fig4_quality_ordering_exact_ge_approx_ge_embarrassing() {
    // The Fig. 4 story on a 64-rank 3D decomposition: exact ≡ sequential,
    // approximate ≈ exact, embarrassing strictly worse (striping).
    let (orig, dq, q, eb) = setup(DatasetKind::MirandaLike, &[48, 48, 48], 1e-2, 64);
    let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
    let ssim_seq = ssim(&orig, &seq, 7, 2);

    let run = |strategy| {
        let cfg = DistributedConfig { ranks: 64, strategy, ..Default::default() };
        let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        (ssim(&orig, &out, 7, 2), psnr(&orig.data, &out.data), out)
    };
    let (ssim_exact, _, out_exact) = run(Strategy::Exact);
    let (ssim_approx, _, _) = run(Strategy::Approximate);
    let (ssim_embar, _, _) = run(Strategy::Embarrassing);

    assert_eq!(out_exact.data, seq.data, "exact must be sequential-identical");
    assert!((ssim_exact - ssim_seq).abs() < 1e-12);
    assert!(
        ssim_approx >= ssim_embar,
        "approx {ssim_approx:.4} < embarrassing {ssim_embar:.4}"
    );
    assert!(
        ssim_exact >= ssim_approx - 1e-6,
        "exact {ssim_exact:.4} < approx {ssim_approx:.4}"
    );
    // all strategies must still beat (or match) the unmitigated data
    let ssim_dq = ssim(&orig, &dq, 7, 2);
    assert!(ssim_embar > ssim_dq - 0.02);
}

#[test]
fn comm_volume_ordering_matches_paper() {
    // exact ≫ approximate > embarrassing (= 0)
    let (_orig, dq, q, eb) = setup(DatasetKind::TurbulenceLike, &[32, 32, 32], 1e-2, 3);
    let vol = |strategy| {
        let cfg = DistributedConfig { ranks: 8, strategy, ..Default::default() };
        let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        rep.total_bytes()
    };
    let v_embar = vol(Strategy::Embarrassing);
    let v_approx = vol(Strategy::Approximate);
    let v_exact = vol(Strategy::Exact);
    assert_eq!(v_embar, 0);
    assert!(v_approx > 0);
    assert!(v_exact > 4 * v_approx, "exact {v_exact} vs approx {v_approx}");
}

#[test]
fn works_on_2d_decompositions() {
    let (orig, dq, q, eb) = setup(DatasetKind::ClimateLike, &[128, 128], 1e-2, 5);
    for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
        let cfg = DistributedConfig { ranks: 16, strategy, ..Default::default() };
        let (out, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        assert_eq!(out.shape, dq.shape);
        assert!(rep.ranks <= 16);
        let bound = (1.0 + 0.9) * eb.abs;
        assert!(qai::metrics::max_abs_error(&orig.data, &out.data) <= bound * (1.0 + 1e-5));
    }
}

#[test]
fn uneven_block_sizes_are_handled() {
    // 23 is prime: blocks differ in size along every axis.
    let (_orig, dq, q, eb) = setup(DatasetKind::HurricaneLike, &[23, 23, 23], 1e-2, 6);
    let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
    let cfg = DistributedConfig { ranks: 8, strategy: Strategy::Exact, ..Default::default() };
    let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
    assert_eq!(out.data, seq.data);
}

#[test]
fn many_ranks_small_domain_degrades_gracefully() {
    let (_orig, dq, q, eb) = setup(DatasetKind::MirandaLike, &[6, 6, 6], 1e-2, 7);
    let cfg =
        DistributedConfig { ranks: 512, strategy: Strategy::Approximate, ..Default::default() };
    let (out, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
    assert!(rep.ranks <= 216);
    assert_eq!(out.shape, dq.shape);
}

#[test]
fn homogeneous_field_no_deadlock() {
    // A constant index field means "no boundaries anywhere": every rank
    // takes the early-exit path, which must still participate in the
    // sign-halo round (a missed send would deadlock a neighbor).
    let dq = Grid::from_vec(vec![1.0f32; 16 * 16 * 16], &[16, 16, 16]);
    let q = Grid::from_vec(vec![7i64; 16 * 16 * 16], &[16, 16, 16]);
    let eb = ErrorBound::absolute(0.5).resolve(&dq.data);
    for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
        let cfg = DistributedConfig { ranks: 8, strategy, ..Default::default() };
        let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        assert_eq!(out.data, dq.data, "{strategy:?}");
    }
}

#[test]
fn boundary_only_in_one_rank_block() {
    // One step in a corner: other ranks have homogeneous indices and must
    // still cooperate (approximate needs both halo rounds everywhere).
    let n = 16;
    let mut q = Grid::<QIndex>::zeros(&[n, n, n]);
    for i in 0..4 {
        for j in 0..4 {
            for k in 0..4 {
                *q.at_mut(i, j, k) = 1;
            }
        }
    }
    let dq = Grid::from_vec(q.data.iter().map(|&v| v as f32 * 0.2).collect(), &[n, n, n]);
    let eb = ErrorBound::absolute(0.1).resolve(&dq.data);
    let seq = mitigate(&dq, &q, eb, &MitigationConfig::default());
    let cfg = DistributedConfig { ranks: 8, strategy: Strategy::Exact, ..Default::default() };
    let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
    assert_eq!(out.data, seq.data);
}
