//! SLO-layer integration tests: token-bucket tenant quotas (burst,
//! refill, per-tenant isolation, weighted fair shares),
//! deadline-infeasibility shedding at admission, adaptive lane
//! scaling, latency-histogram metrics, and engine-vs-direct exactness
//! with every SLO knob switched on.

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{self, Engine, MitigationRequest};
use qai::mitigation::{Job, SubmitError};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::pool::ThreadPool;
use std::sync::Arc;
use std::time::Duration;

fn make_job(dims: &[usize], seed: u64) -> Job {
    let orig = generate(DatasetKind::ClimateLike, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    Job::new(dq, q, eb)
}

/// A single homogeneous element: the pipeline is an early-out identity,
/// so these jobs are effectively zero-duration.
fn tiny_job() -> Job {
    let dq = Grid::from_vec(vec![1.5f32], &[1]);
    let q = Grid::from_vec(vec![0i64], &[1]);
    let eb = ErrorBound::absolute(0.5).resolve(&dq.data);
    Job::new(dq, q, eb)
}

fn tiny_request() -> MitigationRequest {
    MitigationRequest::from_job(tiny_job())
}

#[test]
fn token_bucket_admits_burst_then_rejects_then_refills() {
    // 2 tokens/s, burst 2: the bucket starts full, so two submissions
    // are admitted back-to-back; the third finds an empty bucket.
    let engine = Engine::builder().start_paused(true).quota_rate("acme", 2.0, 2).build();
    let _t1 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    let _t2 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    let err = engine.try_submit(tiny_request().tenant("acme")).unwrap_err();
    assert!(matches!(err, SubmitError::QuotaExceeded(_)), "got {err:?}");

    let ts = engine.tenant_stats("acme").unwrap();
    assert_eq!(ts.quota, Some(2), "bucket size doubles as the quota field");
    assert!((ts.rate - 2.0).abs() < 1e-12, "rate={}", ts.rate);
    assert_eq!(ts.submitted, 2);
    assert_eq!(ts.rejected_quota, 1);
    assert!(ts.tokens < 1.0, "tokens={}", ts.tokens);

    // Lazy refill: at 2 tokens/s, ~0.7 s regenerates at least one
    // token — no refill thread exists, elapsed time is the source.
    std::thread::sleep(Duration::from_millis(700));
    assert!(engine.tenant_stats("acme").unwrap().tokens >= 1.0);
    let _t3 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    assert_eq!(engine.tenant_stats("acme").unwrap().submitted, 3);
}

#[test]
fn token_buckets_are_per_tenant_under_contention() {
    // A near-zero rate freezes the buckets at their initial burst, so
    // each tenant gets exactly its burst — one tenant exhausting its
    // bucket cannot eat into the other's.
    let engine = Engine::builder()
        .start_paused(true)
        .default_quota_rate(1e-6)
        .default_quota_burst(3)
        .build();
    let mut admitted = [0u32; 2];
    let mut rejected = [0u32; 2];
    for attempt in 0..10 {
        let tenant = ["hot", "cold"][attempt % 2];
        match engine.try_submit(tiny_request().tenant(tenant)) {
            Ok(_ticket) => admitted[attempt % 2] += 1,
            Err(SubmitError::QuotaExceeded(_)) => rejected[attempt % 2] += 1,
            Err(e) => panic!("unexpected rejection: {e:?}"),
        }
    }
    assert_eq!(admitted, [3, 3], "each tenant gets exactly its burst");
    assert_eq!(rejected, [2, 2]);
    for tenant in ["hot", "cold"] {
        let ts = engine.tenant_stats(tenant).unwrap();
        assert_eq!((ts.submitted, ts.rejected_quota), (3, 2), "tenant={tenant}");
    }
}

#[test]
fn quota_weight_scales_the_default_rate() {
    let engine = Engine::builder()
        .default_quota_rate(10.0)
        .default_quota_burst(5)
        .quota_weight("gold", 2.0)
        .build();
    // Weighted entries are materialized at build time.
    let gold = engine.tenant_stats("gold").unwrap();
    assert!((gold.rate - 20.0).abs() < 1e-9, "rate={}", gold.rate);
    assert_eq!(gold.quota, Some(5));
    // A dynamically seen tenant gets the unweighted default.
    engine.run(tiny_request().tenant("newbie")).unwrap();
    let newbie = engine.tenant_stats("newbie").unwrap();
    assert!((newbie.rate - 10.0).abs() < 1e-9, "rate={}", newbie.rate);
}

#[test]
fn infeasible_deadline_is_shed_at_admission_without_executing() {
    let engine = Engine::builder().pool(Arc::new(ThreadPool::new(2))).shed(true).build();
    // Warm the (tenant, shape) estimator with one completed job.
    let warm = MitigationRequest::from_job(make_job(&[24, 24], 1)).tenant("acme");
    engine.run(warm).unwrap();
    engine.pause();
    assert_eq!(engine.stats().aggregate().completed, 1);

    // A 1 ns deadline on the warmed key is provably unmeetable.
    let doomed = || {
        MitigationRequest::from_job(make_job(&[24, 24], 2))
            .tenant("acme")
            .deadline(Duration::from_nanos(1))
    };
    let err = engine.try_submit(doomed()).unwrap_err();
    assert!(matches!(err, SubmitError::DeadlineInfeasible(_)), "got {err:?}");
    // The blocking path sheds identically (before waiting for space).
    let err = engine.submit(doomed()).unwrap_err();
    assert!(matches!(err, SubmitError::DeadlineInfeasible(_)), "got {err:?}");

    let st = engine.stats().aggregate();
    assert_eq!(st.shed_infeasible, 2);
    assert_eq!(st.submitted, 1, "shed jobs never enter the queue");
    assert_eq!(st.completed, 1, "shed jobs never execute");

    // The same key with a generous deadline is admitted…
    let fine = MitigationRequest::from_job(make_job(&[24, 24], 3))
        .tenant("acme")
        .deadline(Duration::from_secs(3600));
    let ticket = engine.try_submit(fine).unwrap();
    // …and a cold key is admitted even with the 1 ns deadline:
    // infeasibility must be proven by history, never guessed.
    let cold = MitigationRequest::from_job(make_job(&[16, 16], 4))
        .tenant("acme")
        .deadline(Duration::from_nanos(1));
    let cold_ticket = engine.try_submit(cold).unwrap();

    engine.resume();
    assert!(ticket.wait().is_ok());
    let cold_resp = cold_ticket.wait().unwrap();
    assert!(cold_resp.deadline_missed, "the cold-key job ran (and missed) instead of shedding");
    assert_eq!(engine.stats().aggregate().shed_infeasible, 2);
}

#[test]
fn adaptive_lane_cap_shrinks_when_idle_and_grows_on_misses() {
    let engine = Engine::builder().lanes_per_shard(4).adaptive_lanes(true).build();
    // Wave 1: one job, then idleness — the parked scheduler gives at
    // least one lane back before sleeping. Poll briefly: the shrink
    // happens on the scheduler's post-completion wakeup.
    engine.run(tiny_request()).unwrap();
    let mut shrunk = 0;
    for _ in 0..100 {
        shrunk = engine.shard_stats(0).lanes_shrunk;
        if shrunk >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let st = engine.shard_stats(0);
    assert!(shrunk >= 1, "idle shard must shrink: {st:?}");
    assert!((1..=4).contains(&st.lane_cap), "cap stays clamped: {st:?}");

    // Wave 2: a zero deadline is always missed; a later dispatch cycle
    // sees the fresh miss and grows the cap into parked workers. The
    // grow condition also needs a parked worker at the instant of the
    // check, so drive miss + dispatch waves until one lands.
    let mut grown = 0;
    for _ in 0..50 {
        engine.run(tiny_request().deadline(Duration::ZERO)).unwrap();
        engine.run(tiny_request()).unwrap();
        grown = engine.shard_stats(0).lanes_grown;
        if grown >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let st = engine.shard_stats(0);
    assert!(st.deadlines_missed >= 1, "zero-deadline jobs must miss: {st:?}");
    assert!(grown >= 1, "missed deadlines must grow the cap: {st:?}");
    assert!((1..=4).contains(&st.lane_cap), "cap stays clamped: {st:?}");
}

#[test]
fn adaptive_cap_is_zero_and_static_when_disabled() {
    let engine = Engine::builder().lanes_per_shard(2).build();
    engine.run(tiny_request()).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    let st = engine.shard_stats(0);
    assert_eq!(st.lane_cap, 0, "gauge stays 0 with adaptive scaling off");
    assert_eq!(st.lanes_grown, 0);
    assert_eq!(st.lanes_shrunk, 0);
}

#[test]
fn metrics_report_latency_split_and_bucket_state() {
    let engine =
        Engine::builder().default_quota_rate(100.0).default_quota_burst(8).build();
    engine.run(tiny_request().tenant("acme")).unwrap();
    engine.run(tiny_request().interactive()).unwrap();

    // Structured accessors first.
    let lat = engine.shard_latency(0);
    assert_eq!(lat.bulk.wait.count(), 1);
    assert_eq!(lat.bulk.exec.count(), 1);
    assert_eq!(lat.interactive.wait.count(), 1);
    let acme = engine.tenant_latency("acme").expect("tenant completed a job");
    assert_eq!(acme.wait.count(), 1);
    assert!(engine.tenant_latency("ghost").is_none());

    // Then the scrape surface.
    let text = engine.metrics_text();
    let lines: Vec<&str> = text.lines().collect();
    assert!(
        lines.iter().any(|l| l.starts_with("scope=latency shard=0 class=bulk ")),
        "text={text}"
    );
    assert!(
        lines.iter().any(|l| l.starts_with("scope=latency shard=0 class=interactive ")),
        "text={text}"
    );
    let tenant_line = lines
        .iter()
        .find(|l| l.starts_with("tenant=acme "))
        .unwrap_or_else(|| panic!("no tenant line: {text}"));
    for needle in [" rate=", " tokens=", " wait_p50_ms=", " wait_p99_ms=", " exec_p99_ms="] {
        assert!(tenant_line.contains(needle), "missing {needle}: {tenant_line}");
    }
    // The aggregate line carries the new SLO counters, and every token
    // on every line stays independently scrapeable.
    assert!(lines[0].contains(" shed_infeasible=0 "), "line={}", lines[0]);
    assert!(lines[0].contains(" lane_cap="), "line={}", lines[0]);
    for line in &lines {
        for token in line.split_whitespace() {
            let (key, value) = token.split_once('=').expect("key=value tokens");
            assert!(!key.is_empty() && !value.is_empty(), "token={token} line={line}");
        }
    }
}

#[test]
fn slo_knobs_never_change_pipeline_outputs() {
    // Shed + adaptive lanes + token buckets on: outputs must stay
    // bit-identical to the queue-free direct path.
    let engine = Engine::builder()
        .shards(2)
        .shed(true)
        .adaptive_lanes(true)
        .default_quota_rate(1e6)
        .default_quota_burst(64)
        .build();
    for seed in 0..3 {
        let job = make_job(&[24, 24], seed);
        let direct = engine::execute(&MitigationRequest::from_job(job.clone())).unwrap();
        let queued = engine
            .run(
                MitigationRequest::from_job(job)
                    .tenant("acme")
                    .deadline(Duration::from_secs(3600)),
            )
            .unwrap();
        assert_eq!(queued.output.data, direct.output.data, "seed {seed} diverged");
    }
    assert_eq!(engine.stats().aggregate().shed_infeasible, 0);
}
