//! Quality-metric test battery (ISSUE 7).
//!
//! Three layers:
//!
//! 1. **Exactness matrix** — the fused pooled SSIM kernel
//!    (`metrics::ssim_fast`) must agree with the reference
//!    `metrics::ssim` across datasets × dimensionalities × thread
//!    counts. The kernel replays the reference's per-line rolling-sum
//!    arithmetic and sums anchor scores in anchor order, so agreement
//!    is bit-identical (`assert_eq!` on `f64`), far inside the 1e-9
//!    acceptance band — and independent of pool scheduling/stealing.
//! 2. **Golden/edge cases** for `metrics::{psnr, mse, max_abs_error,
//!    ssim}`: identical inputs, empty inputs (regression: `mse` used to
//!    panic), constant fields, window larger than every dim, 1-element
//!    grids.
//! 3. **Quality-targeted serving** — a request carrying a
//!    `QualityTarget` converges to its floor, the bounded parameter
//!    search runs exactly once per (tenant, shape) key, and the
//!    `quality_hits`/`quality_misses` counters prove the second
//!    request was served from the learned cache.

use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{max_abs_error, mse, psnr, ssim, ssim_fast, ssim_fast_on, ssim_gaussian_threads};
use qai::mitigation::engine::{self, Engine, MitigationRequest};
use qai::mitigation::QualityTarget;
use qai::quant::{quantize_grid, ErrorBound, QIndex};
use qai::util::arena::{Arena, ArenaHandle};
use qai::util::pool::{PoolHandle, ThreadPool};
use qai::SharedGrid;

/// Synthesize → quantize one field; returns (original, q, dq).
fn make_case(kind: DatasetKind, dims: &[usize], seed: u64) -> (Grid<f32>, Grid<QIndex>, Grid<f32>) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (orig, q, dq)
}

// ---------------------------------------------------------------------------
// 1. Exactness matrix
// ---------------------------------------------------------------------------

#[test]
fn fused_ssim_matches_reference_across_datasets_dims_threads() {
    let cases: Vec<(DatasetKind, Vec<usize>, u64)> = vec![
        (DatasetKind::ClimateLike, vec![33, 29], 5),
        (DatasetKind::TurbulenceLike, vec![64, 48], 8),
        (DatasetKind::MirandaLike, vec![17, 15, 13], 6),
        (DatasetKind::CombustionLike, vec![24, 24, 24], 7),
    ];
    for (kind, dims, seed) in cases {
        let (orig, _q, dq) = make_case(kind, &dims, seed);
        for (window, stride) in [(7usize, 2usize), (11, 4), (3, 1)] {
            let reference = ssim(&orig, &dq, window, stride);
            for threads in [1usize, 2, 4] {
                let pool = ThreadPool::new(threads);
                let arena = Arena::new();
                let got = ssim_fast_on(
                    PoolHandle::Explicit(&pool),
                    ArenaHandle::Pooled(&arena),
                    &orig,
                    &dq,
                    window,
                    stride,
                    threads,
                );
                assert!(
                    (got - reference).abs() <= 1e-9,
                    "{kind:?} {dims:?} w={window} s={stride} t={threads}: {got} vs {reference}"
                );
                // The acceptance band is 1e-9; the construction is in
                // fact bit-identical — pin the stronger property.
                assert_eq!(
                    got.to_bits(),
                    reference.to_bits(),
                    "{kind:?} {dims:?} w={window} s={stride} t={threads}"
                );
            }
        }
    }
}

#[test]
fn fused_ssim_deterministic_on_shared_pool() {
    // Repeated runs on one multi-lane pool (where line batches land on
    // whichever worker steals them) must produce identical bits, and
    // match the serial global-pool entry point.
    let (orig, _q, dq) = make_case(DatasetKind::MirandaLike, &[40, 40, 40], 3);
    let serial = ssim_fast(&orig, &dq, 7, 2);
    let pool = ThreadPool::new(4);
    let arena = Arena::new();
    for run in 0..8 {
        let got = ssim_fast_on(
            PoolHandle::Explicit(&pool),
            ArenaHandle::Pooled(&arena),
            &orig,
            &dq,
            7,
            2,
            4,
        );
        assert_eq!(got.to_bits(), serial.to_bits(), "run {run} diverged from serial");
    }
    assert_eq!(serial.to_bits(), ssim(&orig, &dq, 7, 2).to_bits());
}

#[test]
fn gaussian_ssim_thread_invariant_and_orders_quality() {
    let (orig, _q, dq) = make_case(DatasetKind::CombustionLike, &[28, 28, 14], 9);
    let one = ssim_gaussian_threads(&orig, &dq, 1);
    for threads in [2usize, 4, 8] {
        assert_eq!(
            one.to_bits(),
            ssim_gaussian_threads(&orig, &dq, threads).to_bits(),
            "gaussian SSIM must not depend on thread count (threads={threads})"
        );
    }
    // Sanity ordering: identical fields score 1, degraded fields less.
    assert_eq!(ssim_gaussian_threads(&orig, &orig, 2), 1.0);
    assert!(one < 1.0 && one > 0.0, "degraded field must land in (0, 1): {one}");
}

// ---------------------------------------------------------------------------
// 2. Golden / edge cases for the scalar metrics
// ---------------------------------------------------------------------------

#[test]
fn psnr_identical_inputs_is_infinite() {
    let a: Vec<f32> = (0..256).map(|i| (i as f32).sin()).collect();
    assert_eq!(psnr(&a, &a), f64::INFINITY);
}

#[test]
fn empty_inputs_are_defined() {
    // Regression: `mse` asserted (panicked) on empty slices, which made
    // `psnr` on empty inputs panic too. Empty fields are identical by
    // definition: MSE 0, max-abs 0, PSNR +inf.
    assert_eq!(mse(&[], &[]), 0.0);
    assert_eq!(max_abs_error(&[], &[]), 0.0);
    assert_eq!(psnr(&[], &[]), f64::INFINITY);
}

#[test]
fn constant_fields_are_defined() {
    let a = Grid::from_vec(vec![1.0f32; 27], &[3, 3, 3]);
    let b = Grid::from_vec(vec![2.0f32; 27], &[3, 3, 3]);
    // Zero-range original: SSIM is 1 iff the fields are identical
    // (QCAT convention), for both the reference and fused kernels.
    assert_eq!(ssim(&a, &a, 7, 2), 1.0);
    assert_eq!(ssim(&a, &b, 7, 2), 0.0);
    assert_eq!(ssim_fast(&a, &a, 7, 2), 1.0);
    assert_eq!(ssim_fast(&a, &b, 7, 2), 0.0);
    // Range-based PSNR against a constant original degenerates to
    // -inf when there is any error (log of a zero range) — defined,
    // never a panic or NaN.
    assert_eq!(psnr(&a.data, &b.data), f64::NEG_INFINITY);
    assert_eq!(psnr(&a.data, &a.data), f64::INFINITY);
}

#[test]
fn window_larger_than_every_dim_clamps() {
    let (orig, _q, dq) = make_case(DatasetKind::ClimateLike, &[4, 3], 2);
    for stride in [1usize, 2] {
        let reference = ssim(&orig, &dq, 11, stride);
        assert!(reference.is_finite());
        assert_eq!(ssim_fast(&orig, &dq, 11, stride).to_bits(), reference.to_bits());
    }
}

#[test]
fn one_element_grids_are_defined() {
    let a = Grid::from_vec(vec![0.75f32], &[1]);
    let b = Grid::from_vec(vec![0.5f32], &[1]);
    assert_eq!(ssim(&a, &a, 7, 2), 1.0);
    assert_eq!(ssim(&a, &b, 7, 2), 0.0);
    assert_eq!(ssim_fast(&a, &a, 7, 2), 1.0);
    assert_eq!(ssim_fast(&a, &b, 7, 2), 0.0);
    assert_eq!(mse(&a.data, &b.data), 0.0625);
    assert_eq!(max_abs_error(&a.data, &b.data), 0.25);
    assert_eq!(psnr(&a.data, &a.data), f64::INFINITY);
}

// ---------------------------------------------------------------------------
// 3. Quality-targeted serving
// ---------------------------------------------------------------------------

#[test]
fn quality_target_converges_and_caches_per_tenant_shape_key() {
    let cases: Vec<(DatasetKind, Vec<usize>, u64)> = vec![
        (DatasetKind::ClimateLike, vec![32, 32], 11),
        (DatasetKind::CombustionLike, vec![16, 16, 16], 12),
    ];
    for (kind, dims, seed) in cases {
        let (orig, q, dq) = make_case(kind, &dims, seed);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let dq: SharedGrid<f32> = dq.into();
        let q: SharedGrid<QIndex> = q.into();
        let orig_shared: SharedGrid<f32> = orig.into();

        let engine = Engine::builder().build();
        // Measure what the default config achieves, then target just
        // below it: a reachable floor every run must meet.
        let plain = engine.run(MitigationRequest::new(dq.clone(), q.clone(), eb)).unwrap();
        assert_eq!(plain.quality, None, "no reference attached, nothing to score");
        let reachable = psnr(&orig_shared.data, &plain.output.data);
        assert!(reachable.is_finite());
        let target = QualityTarget::Psnr(reachable - 1.0);

        let request = || {
            MitigationRequest::new(dq.clone(), q.clone(), eb)
                .tenant("acme")
                .reference(orig_shared.clone())
                .quality_target(target)
        };

        // First quality-targeted request: cache miss, one search.
        let r1 = engine.run(request()).unwrap();
        let q1 = r1.quality.expect("quality-targeted responses carry a score");
        assert!(q1 >= reachable - 1.0, "{kind:?}: quality {q1} below target {target:?}");
        let st = engine.stats().aggregate();
        assert_eq!(
            (st.quality_misses, st.quality_hits, st.quality_evicted),
            (1, 0, 0),
            "{kind:?}: first request must run the search exactly once"
        );

        // Second request, same (tenant, shape): served from the cache —
        // the hit counter moves, the miss counter does not.
        let r2 = engine.run(request()).unwrap();
        let q2 = r2.quality.expect("cache-hit responses still report quality");
        assert!(q2 >= reachable - 1.0);
        let st = engine.stats().aggregate();
        assert_eq!(
            (st.quality_misses, st.quality_hits),
            (1, 1),
            "{kind:?}: second request must skip the search"
        );

        // A new shape under the same tenant is a new key → new search.
        let small_dims: Vec<usize> = dims.iter().map(|&d| (d / 2).max(4)).collect();
        let (sorig, sq, sdq) = make_case(kind, &small_dims, seed + 1);
        let seb = ErrorBound::relative(1e-2).resolve(&sorig.data);
        let r3 = engine
            .run(
                MitigationRequest::new(sdq, sq, seb)
                    .tenant("acme")
                    .reference(sorig)
                    // An unreachable floor exercises the exhaustive
                    // branch: best-seen wins, the request still
                    // succeeds, quality is reported.
                    .quality_target(QualityTarget::Psnr(f64::INFINITY)),
            )
            .unwrap();
        assert!(r3.quality.unwrap().is_finite());
        let st = engine.stats().aggregate();
        assert_eq!(
            (st.quality_misses, st.quality_hits),
            (2, 1),
            "{kind:?}: a new shape must be a fresh cache key"
        );
    }
}

#[test]
fn quality_target_without_reference_fails_cleanly() {
    let (_orig, q, dq) = make_case(DatasetKind::ClimateLike, &[16, 16], 4);
    let eb = ErrorBound::relative(1e-2).resolve(&dq.data);
    let engine = Engine::builder().build();
    let err = engine
        .run(
            MitigationRequest::new(dq, q, eb).quality_target(QualityTarget::Ssim(0.9)),
        )
        .expect_err("a target with no reference cannot be scored");
    assert!(
        err.to_string().contains("requires a reference"),
        "error must name the missing field: {err:#}"
    );
    let st = engine.stats().aggregate();
    assert_eq!(st.failed, 1, "the job fails; the service survives");
}

#[test]
fn plain_request_with_reference_reports_quality_without_searching() {
    let (orig, q, dq) = make_case(DatasetKind::MirandaLike, &[12, 12, 12], 6);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let engine = Engine::builder().build();
    let resp = engine
        .run(MitigationRequest::new(dq, q, eb).reference(orig))
        .unwrap();
    let quality = resp.quality.expect("reference attached → scored");
    assert!(quality > 0.0 && quality <= 1.0, "default score is gaussian SSIM: {quality}");
    let st = engine.stats().aggregate();
    assert_eq!(
        (st.quality_misses, st.quality_hits),
        (0, 0),
        "scoring without a target must not touch the search or cache"
    );
}

#[test]
fn queue_free_execute_runs_search_inline() {
    let (orig, q, dq) = make_case(DatasetKind::CombustionLike, &[14, 14, 14], 13);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let orig_shared: SharedGrid<f32> = orig.into();
    let plain =
        engine::execute(&MitigationRequest::new(dq.clone(), q.clone(), eb)).unwrap();
    let reachable = psnr(&orig_shared.data, &plain.output.data);
    let resp = engine::execute(
        &MitigationRequest::new(dq, q, eb)
            .reference(orig_shared.clone())
            .quality_target(QualityTarget::Psnr(reachable - 1.0)),
    )
    .unwrap();
    assert_eq!(resp.shard, None, "execute bypasses the shards");
    assert!(resp.quality.unwrap() >= reachable - 1.0);
}
