//! Engine front-door integration tests: the bit-exactness matrix
//! against the legacy paths (datasets × dims × shard counts), router
//! determinism under concurrent tenants, quota rejection round-trips,
//! EDF ordering on a single-lane pool, shared-arena reuse across
//! shards, and the labeled metrics format.

// The legacy entry points (`mitigate_with_stats`, the service
// constructors, `mitigate_batch`) are the references the exactness
// matrix compares the engine against.
#![allow(deprecated)]

use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{Engine, MitigationRequest};
use qai::mitigation::{
    mitigate_with_stats, Job, MitigationConfig, MitigationService, SubmitError,
};
use qai::quant::{quantize_grid, ErrorBound, ResolvedBound};
use qai::Grid;
use std::time::{Duration, Instant};

fn field(kind: DatasetKind, dims: &[usize], seed: u64) -> (Grid<f32>, Grid<i64>, ResolvedBound) {
    let orig = generate(kind, dims, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (dq, q, eb)
}

/// A trivially fast job: a single homogeneous element is an early-out
/// identity, keeping scheduling-focused tests quick.
fn tiny_request() -> MitigationRequest {
    let dq = Grid::from_vec(vec![1.5f32], &[1]);
    let q = Grid::from_vec(vec![0i64], &[1]);
    let eb = ErrorBound::absolute(0.5).resolve(&dq.data);
    MitigationRequest::new(dq, q, eb)
}

/// Poll until the tenant's in-flight gauge drains. The quota lease is
/// released *before* the ticket resolves, so after a `wait()` this
/// returns immediately — the poll is belt-and-braces for jobs whose
/// tickets nobody waited on.
fn wait_in_flight_zero(engine: &Engine, tenant: &str) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let stats = engine.tenant_stats(tenant).expect("tenant must be known");
        if stats.in_flight == 0 {
            return;
        }
        assert!(Instant::now() < deadline, "tenant {tenant} in-flight never drained");
        std::thread::yield_now();
    }
}

#[test]
fn engine_is_bit_identical_to_legacy_paths_across_shard_counts() {
    let cases: &[(DatasetKind, &[usize])] = &[
        (DatasetKind::ClimateLike, &[40, 40]),
        (DatasetKind::MirandaLike, &[18, 18, 18]),
        (DatasetKind::CombustionLike, &[14, 14, 14]),
        (DatasetKind::HurricaneLike, &[200]),
    ];
    for &(kind, dims) in cases {
        for threads in [1usize, 2] {
            let cfg = MitigationConfig { threads, ..Default::default() };
            let (dq, q, eb) = field(kind, dims, 11);

            // Legacy reference #1: the direct free function.
            let (direct, direct_stats) = mitigate_with_stats(&dq, &q, eb, &cfg).unwrap();
            // Legacy reference #2: the batch wrapper.
            let job = Job::with_config(dq.clone(), q.clone(), eb, cfg);
            let legacy_batch = MitigationService::new().mitigate_batch(std::slice::from_ref(&job));
            let (legacy_out, _) = legacy_batch.into_iter().next().unwrap().unwrap();
            assert_eq!(legacy_out.data, direct.data);

            for shards in [1usize, 2, 3] {
                let engine = Engine::builder().shards(shards).build();
                // One tenant per shard-count so the router exercises
                // different placements; plus one tenant-less request
                // through the least-loaded fallback.
                let resp = engine
                    .run(
                        MitigationRequest::from_job(job.clone())
                            .tenant(format!("tenant-{shards}"))
                            .with_stats(true),
                    )
                    .unwrap();
                assert_eq!(
                    resp.output.data, direct.data,
                    "kind={kind:?} dims={dims:?} threads={threads} shards={shards}"
                );
                let stats = resp.stats.expect("stats requested");
                assert_eq!(stats.n_boundary1, direct_stats.n_boundary1);
                assert_eq!(stats.n_boundary2, direct_stats.n_boundary2);

                let resp2 = engine.run(MitigationRequest::from_job(job.clone())).unwrap();
                assert_eq!(resp2.output.data, direct.data, "tenant-less routing diverged");
                assert!(resp2.shard.unwrap() < shards);
            }
        }
    }
}

#[test]
fn run_batch_matches_legacy_mitigate_batch_slotwise() {
    let jobs: Vec<Job> = vec![
        {
            let (dq, q, eb) = field(DatasetKind::ClimateLike, &[32, 32], 1);
            Job::new(dq, q, eb)
        },
        {
            let (dq, q, eb) = field(DatasetKind::TurbulenceLike, &[12, 12, 12], 2);
            Job::new(dq, q, eb)
        },
        {
            let (dq, q, eb) = field(DatasetKind::CosmologyLike, &[10, 14, 12], 3);
            Job::new(dq, q, eb)
        },
    ];
    let legacy = MitigationService::new().mitigate_batch(&jobs);
    let engine = Engine::builder().shards(2).build();
    let requests: Vec<MitigationRequest> =
        jobs.iter().map(|j| MitigationRequest::from_job(j.clone())).collect();
    let got = engine.run_batch(requests);
    assert_eq!(got.len(), legacy.len());
    for (i, (l, g)) in legacy.iter().zip(&got).enumerate() {
        assert_eq!(
            l.as_ref().unwrap().0.data,
            g.as_ref().unwrap().output.data,
            "slot {i} diverged from the legacy batch path"
        );
    }
}

#[test]
fn router_is_deterministic_for_tenants_under_concurrency() {
    let engine = Engine::builder().shards(4).build();
    let tenants: Vec<String> = (0..6).map(|t| format!("tenant-{t}")).collect();

    std::thread::scope(|s| {
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let engine = &engine;
                let tenants = &tenants;
                s.spawn(move || {
                    let mut seen = Vec::new();
                    for tenant in tenants {
                        let ticket = engine
                            .try_submit(tiny_request().tenant(tenant.clone()))
                            .expect("submission must be admitted");
                        seen.push((tenant.clone(), ticket.shard()));
                        assert!(ticket.wait().is_ok());
                    }
                    seen
                })
            })
            .collect();
        for handle in handles {
            for (tenant, shard) in handle.join().unwrap() {
                assert_eq!(
                    shard,
                    engine.shard_for_tenant(&tenant),
                    "tenant {tenant} migrated off its consistent-hash shard"
                );
            }
        }
    });
}

#[test]
fn quota_rejection_roundtrips_the_job_and_releases_on_completion() {
    // Paused engine: admitted jobs stay in flight, so the third "acme"
    // submission deterministically trips the quota of 2.
    let engine = Engine::builder()
        .shards(1)
        .capacity(8)
        .start_paused(true)
        .quota("acme", 2)
        .build();

    let t1 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    let t2 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    let err = engine.try_submit(tiny_request().tenant("acme")).unwrap_err();
    assert!(matches!(err, SubmitError::QuotaExceeded(_)), "got {err:?}");
    assert_eq!(err.to_string(), "per-tenant admission quota exceeded");

    // The rejected job round-trips intact and other tenants are
    // unaffected.
    let recovered = err.into_job();
    assert_eq!(recovered.dq.len(), 1);
    let other = engine.try_submit(tiny_request().tenant("other")).unwrap();

    let acme = engine.tenant_stats("acme").unwrap();
    assert_eq!(acme.quota, Some(2));
    assert_eq!(acme.submitted, 2);
    assert_eq!(acme.rejected_quota, 1);
    assert_eq!(acme.in_flight, 2);

    engine.resume();
    assert!(t1.wait().is_ok());
    assert!(t2.wait().is_ok());
    assert!(other.wait().is_ok());
    wait_in_flight_zero(&engine, "acme");

    // Slots freed: the recovered job is admitted now.
    let retry = engine
        .try_submit(MitigationRequest::from_job(recovered).tenant("acme"))
        .expect("quota slot must free after completion");
    assert!(retry.wait().is_ok());
    wait_in_flight_zero(&engine, "acme");
    let acme = engine.tenant_stats("acme").unwrap();
    assert_eq!((acme.submitted, acme.rejected_quota), (3, 1));

    // A failed admission must release its quota slot too: fill the
    // 1-deep queue... (capacity 8, so trip it via quota instead: two
    // in-flight on a paused engine again.)
    engine.pause();
    let h1 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    let h2 = engine.try_submit(tiny_request().tenant("acme")).unwrap();
    assert!(engine.try_submit(tiny_request().tenant("acme")).is_err());
    engine.resume();
    assert!(h1.wait().is_ok());
    assert!(h2.wait().is_ok());
    wait_in_flight_zero(&engine, "acme");
}

#[test]
fn serial_client_at_quota_one_never_sees_spurious_rejection() {
    // The quota lease releases before the ticket resolves, so a
    // wait-then-resubmit loop at quota 1 must always be admitted.
    let engine = Engine::builder().shards(1).quota("serial", 1).build();
    for i in 0..16 {
        let ticket = engine
            .try_submit(tiny_request().tenant("serial"))
            .unwrap_or_else(|e| panic!("iteration {i}: spurious rejection: {e}"));
        assert!(ticket.wait().is_ok());
    }
    let stats = engine.tenant_stats("serial").unwrap();
    assert_eq!(stats.submitted, 16);
    assert_eq!(stats.rejected_quota, 0);
}

#[test]
fn edf_orders_deadlines_within_a_class_on_a_single_lane() {
    // Single-lane engine: jobs execute inline in dequeue order, so the
    // per-shard sequence numbers capture the schedule exactly.
    let engine = Engine::builder()
        .shards(1)
        .capacity(16)
        .lanes_per_shard(1)
        .start_paused(true)
        .build();

    let far = engine
        .try_submit(tiny_request().deadline(Duration::from_secs(300)))
        .unwrap();
    let near = engine
        .try_submit(tiny_request().deadline(Duration::from_secs(100)))
        .unwrap();
    let mid = engine
        .try_submit(tiny_request().deadline(Duration::from_secs(200)))
        .unwrap();
    let none = engine.try_submit(tiny_request()).unwrap();
    // Interactive class beats every bulk deadline, even submitted last.
    let urgent = engine.try_submit(tiny_request().interactive()).unwrap();

    engine.resume();
    let seq = |t: qai::mitigation::engine::ResponseTicket| t.wait().unwrap().seq.unwrap();
    let (s_far, s_near, s_mid, s_none, s_urgent) =
        (seq(far), seq(near), seq(mid), seq(none), seq(urgent));

    assert!(s_urgent < s_near, "interactive must overtake every queued bulk job");
    assert!(s_near < s_mid, "EDF: nearest deadline first (near={s_near} mid={s_mid})");
    assert!(s_mid < s_far, "EDF: mid deadline before far (mid={s_mid} far={s_far})");
    assert!(s_far < s_none, "deadline-less bulk jobs drain after all deadline jobs");
}

#[test]
fn shared_arena_recycles_buffers_across_shards() {
    let engine = Engine::builder().shards(2).shared_arena(true).build();
    let (dq, q, eb) = field(DatasetKind::MirandaLike, &[20, 20, 20], 9);
    let job = Job::new(dq, q, eb);

    // Tenants pinned to different shards (consistent hash may collide,
    // so search two ids that differ).
    let t_a = "arena-a".to_string();
    let mut t_b = String::new();
    for i in 0..64 {
        let cand = format!("arena-b{i}");
        if engine.shard_for_tenant(&cand) != engine.shard_for_tenant(&t_a) {
            t_b = cand;
            break;
        }
    }
    assert!(!t_b.is_empty(), "no tenant hashed to the other shard in 64 tries");

    let resp_a = engine
        .run(MitigationRequest::from_job(job.clone()).tenant(t_a.clone()))
        .unwrap();
    engine.recycle(resp_a.output);
    let cold = engine.arena_stats();
    assert!(cold.misses > 0);

    let resp_b = engine.run(MitigationRequest::from_job(job).tenant(t_b.clone())).unwrap();
    assert_ne!(resp_b.shard, resp_a.shard, "tenants must have landed on distinct shards");
    let warm = engine.arena_stats();
    assert_eq!(
        warm.misses, cold.misses,
        "a same-shaped job on the other shard must reuse the shared arena's buffers"
    );
    assert!(warm.hits > cold.hits);
}

#[test]
fn engine_metrics_carry_shard_and_tenant_labels() {
    let engine = Engine::builder().shards(2).quota("acme", 4).build();
    let resp = engine.run(tiny_request().tenant("acme")).unwrap();
    assert!(resp.output.len() == 1);

    let text = engine.metrics_text();
    let lines: Vec<&str> = text.lines().collect();
    assert!(lines.len() >= 4, "aggregate + 2 shards + 1 tenant, got: {text}");
    assert!(lines[0].starts_with("scope=engine shards=2 "), "line={}", lines[0]);
    assert!(lines.iter().any(|l| l.starts_with("shard=0 ")), "text={text}");
    assert!(lines.iter().any(|l| l.starts_with("shard=1 ")), "text={text}");
    assert!(
        lines.iter().any(|l| l.starts_with("tenant=acme ") && l.contains("quota=4")),
        "text={text}"
    );
    // Every line must be independently scrapeable key=value tokens.
    for line in &lines {
        for token in line.split_whitespace() {
            let (key, value) = token.split_once('=').expect("key=value tokens");
            assert!(!key.is_empty() && !value.is_empty(), "token {token:?} in {line:?}");
        }
    }

    // The aggregate line reflects the completed job.
    assert!(lines[0].contains("completed=1"), "line={}", lines[0]);
}

#[test]
fn trace_ids_follow_a_job_from_request_to_response_and_metrics() {
    let engine = Engine::builder().shards(2).build();
    let first = tiny_request();
    let second = tiny_request();
    assert!(first.trace_id() > 0);
    assert!(
        second.trace_id() > first.trace_id(),
        "trace ids must be monotonically assigned ({} then {})",
        first.trace_id(),
        second.trace_id()
    );

    let expected = first.trace_id();
    let ticket = engine.submit(first).unwrap();
    assert_eq!(ticket.trace_id(), expected, "ticket must carry the request's trace id");
    let shard = ticket.shard();
    let response = ticket.wait().unwrap();
    assert_eq!(response.trace_id, expected, "response must carry the request's trace id");

    // The id is observable in the shard stats and the metrics lines,
    // so a job can be followed across shard, queue, and lane.
    assert_eq!(engine.shard_stats(shard).last_trace_id, expected);
    assert_eq!(engine.stats().aggregate().last_trace_id, expected);
    let text = engine.metrics_text();
    assert!(
        text.lines().any(|l| l.contains(&format!("last_trace={expected}"))),
        "metrics must print the trace id: {text}"
    );

    // The synchronous queue-free path reports the id too.
    let direct = qai::mitigation::engine::execute(&second).unwrap();
    assert_eq!(direct.trace_id, second.trace_id());
}

#[test]
fn submit_timeout_and_queue_full_round_trip_through_the_engine() {
    let engine = Engine::builder().shards(1).capacity(1).start_paused(true).build();
    let held = engine.try_submit(tiny_request()).unwrap();
    // Queue full: non-blocking rejects...
    let err = engine.try_submit(tiny_request()).unwrap_err();
    assert!(matches!(err, SubmitError::QueueFull(_)), "got {err:?}");
    // ...and a blocking submit with a short timeout gives up.
    let err = engine
        .submit(tiny_request().submit_timeout(Duration::from_millis(30)))
        .unwrap_err();
    assert!(matches!(err, SubmitError::Timeout(_)), "got {err:?}");
    engine.resume();
    assert!(held.wait().is_ok());
}
