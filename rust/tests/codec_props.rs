//! Property tests for the codec substrates (via `util::prop`): Huffman
//! encode/decode, `bitio` writer/reader, and the Lorenzo
//! predict/reconstruct roundtrip — with explicit empty and
//! single-element coverage.

use qai::compressors::bitio::{unzigzag, zigzag, BitReader, BitWriter};
use qai::compressors::{huffman, lorenzo};
use qai::data::grid::Grid;
use qai::quant::QIndex;
use qai::util::prop::prop_check;

// ---------------------------------------------------------------- huffman

#[test]
fn huffman_empty_and_single_element() {
    // Empty symbol stream.
    let enc = huffman::encode(&[]);
    assert_eq!(huffman::decode(&enc).unwrap(), Vec::<u32>::new());
    // Single-element streams, including extreme symbol values.
    for s in [0u32, 1, 12345, u32::MAX] {
        let enc = huffman::encode(&[s]);
        assert_eq!(huffman::decode(&enc).unwrap(), vec![s], "symbol {s}");
    }
}

#[test]
fn huffman_roundtrip_random_alphabets() {
    prop_check("huffman roundtrip (random alphabets)", 40, |g| {
        let n = g.usize_in(0, 1500);
        // Alphabets from degenerate (1 symbol) to wide/sparse (large
        // symbol values exercise the u32 codebook headers).
        let alpha = g.usize_in(1, 300) as u32;
        let offset = if g.bool_with(0.3) { u32::MAX - 400 } else { 0 };
        let data: Vec<u32> =
            (0..n).map(|_| offset + g.usize_in(0, alpha as usize) as u32).collect();
        let enc = huffman::encode(&data);
        assert_eq!(huffman::decode(&enc).unwrap(), data);
    });
}

#[test]
fn huffman_roundtrip_skewed_distributions() {
    prop_check("huffman roundtrip (skewed)", 25, |g| {
        let n = g.usize_in(1, 2000);
        let p = g.f64_in(0.5, 0.95);
        let data: Vec<u32> = (0..n)
            .map(|_| {
                let mut v = 0u32;
                while g.bool_with(p) && v < 40 {
                    v += 1;
                }
                v
            })
            .collect();
        let enc = huffman::encode(&data);
        let dec = huffman::decode(&enc).unwrap();
        assert_eq!(dec, data);
    });
}

// ------------------------------------------------------------------ bitio

#[test]
fn bitio_empty_writer_and_exhausted_reader() {
    let w = BitWriter::new();
    assert_eq!(w.bit_len(), 0);
    let bytes = w.into_bytes();
    assert!(bytes.is_empty());
    let mut r = BitReader::new(&bytes);
    assert_eq!(r.read_bits(1), None);
    assert_eq!(r.read_bit(), None);
}

#[test]
fn bitio_single_bit_and_full_width() {
    let mut w = BitWriter::new();
    w.write_bit(true);
    w.write_bits(u64::MAX, 64);
    let bytes = w.into_bytes();
    let mut r = BitReader::new(&bytes);
    assert_eq!(r.read_bit(), Some(true));
    assert_eq!(r.read_bits(64), Some(u64::MAX));
}

#[test]
fn bitio_roundtrip_random_streams() {
    prop_check("bitio mixed-width roundtrip", 60, |g| {
        let n = g.usize_in(0, 300);
        let items: Vec<(u64, u32)> = (0..n)
            .map(|_| {
                let w = g.usize_in(1, 64) as u32;
                let mask = if w == 64 { u64::MAX } else { (1u64 << w) - 1 };
                (g.rng().next_u64() & mask, w)
            })
            .collect();
        let mut wtr = BitWriter::new();
        for &(v, w) in &items {
            wtr.write_bits(v, w);
        }
        let total_bits: usize = items.iter().map(|&(_, w)| w as usize).sum();
        assert_eq!(wtr.bit_len(), total_bits);
        let bytes = wtr.into_bytes();
        assert_eq!(bytes.len(), total_bits.div_ceil(8));
        let mut r = BitReader::new(&bytes);
        for &(v, w) in &items {
            assert_eq!(r.read_bits(w), Some(v));
        }
        // Reading past the stream (plus padding) must fail, not wrap.
        assert_eq!(r.read_bits(9), None);
    });
}

#[test]
fn bitio_zigzag_roundtrip_extremes() {
    for v in [0i64, 1, -1, i64::MAX, i64::MIN, 42, -917] {
        assert_eq!(unzigzag(zigzag(v)), v, "v={v}");
    }
    prop_check("zigzag order-preserving near zero", 100, |g| {
        let v = (g.rng().next_u64() as i64) >> g.usize_in(1, 40);
        assert_eq!(unzigzag(zigzag(v)), v);
    });
}

// ---------------------------------------------------------------- lorenzo

#[test]
fn lorenzo_single_element_grids() {
    for dims in [vec![1usize], vec![1, 1], vec![1, 1, 1]] {
        let q: Grid<QIndex> = Grid::from_vec(vec![-37], &dims);
        let r = lorenzo::forward(&q);
        assert_eq!(r, vec![-37], "dims={dims:?}: sole residual is the value itself");
        assert_eq!(lorenzo::inverse(&r, q.shape).data, q.data, "dims={dims:?}");
    }
}

#[test]
fn lorenzo_roundtrip_random_index_fields() {
    prop_check("lorenzo roundtrip (random index fields)", 60, |g| {
        let ndim = g.usize_in(1, 3);
        let dims: Vec<usize> = (0..ndim).map(|_| g.usize_in(1, 12)).collect();
        let n: usize = dims.iter().product();
        // Index magnitudes from tiny to large (NYX-like ranges).
        let scale = *g.choose(&[3i64, 100, 1_000_000, 1 << 40]);
        let vals: Vec<QIndex> = (0..n)
            .map(|_| (g.rng().next_u64() as i64) % scale)
            .collect();
        let q = Grid::from_vec(vals, &dims);
        let r = lorenzo::forward(&q);
        assert_eq!(r.len(), n);
        assert_eq!(lorenzo::inverse(&r, q.shape).data, q.data, "dims={dims:?}");
    });
}

#[test]
fn lorenzo_degenerate_row_and_column_grids() {
    prop_check("lorenzo roundtrip (1xN / Nx1)", 30, |g| {
        let n = g.usize_in(1, 40);
        let vals: Vec<QIndex> = (0..n).map(|_| g.usize_in(0, 500) as i64 - 250).collect();
        for dims in [vec![1, n], vec![n, 1], vec![1, 1, n], vec![1, n, 1], vec![n, 1, 1]] {
            let q = Grid::from_vec(vals.clone(), &dims);
            let r = lorenzo::forward(&q);
            assert_eq!(lorenzo::inverse(&r, q.shape).data, q.data, "dims={dims:?}");
        }
    });
}

#[test]
fn lorenzo_forward_then_inverse_is_identity_even_with_extremes() {
    // Alternating large-magnitude values stress the inclusion–exclusion
    // corner sums without overflowing i64.
    let vals: Vec<QIndex> = (0..27)
        .map(|i| if i % 2 == 0 { 1 << 35 } else { -(1 << 35) })
        .collect();
    let q = Grid::from_vec(vals, &[3, 3, 3]);
    let r = lorenzo::forward(&q);
    assert_eq!(lorenzo::inverse(&r, q.shape).data, q.data);
}
