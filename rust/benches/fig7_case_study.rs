//! Fig. 7 — Hurricane-Wf48 visual case study: points A (low EB),
//! B (moderate), C (very high). The paper's shape: ~no change at A,
//! large SSIM+PSNR gain at B, SSIM-dominant gain at C.

use qai::bench_support::tables::Table;
use qai::compressors::{cusz::CuszLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::quant::ErrorBound;
use qai::SharedGrid;

fn main() {
    let orig = generate(DatasetKind::HurricaneLike, &[64, 128, 128], 48);
    let codec = CuszLike;
    let points = [("A", 1e-3), ("B", 1e-2), ("C", 8e-2)];

    let mut rows = Vec::new();
    let mut table = Table::new(&[
        "point", "rel_eb", "bits/val", "SSIM_q", "SSIM_ours", "dSSIM", "PSNR_q", "PSNR_ours",
        "dPSNR",
    ]);
    for (label, rel) in points {
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let stream = codec.compress(&orig, eb).unwrap();
        let dec = codec.decompress(&stream).unwrap();
        let dq: SharedGrid<f32> = dec.grid.into();
        let request = MitigationRequest::new(dq.clone(), dec.quant_indices, eb);
        let fixed = engine::execute(&request).unwrap().output;
        let s0 = ssim(&orig, &dq, 7, 2);
        let s1 = ssim(&orig, &fixed, 7, 2);
        let p0 = psnr(&orig.data, &dq.data);
        let p1 = psnr(&orig.data, &fixed.data);
        rows.push((label, s1 - s0, p1 - p0));
        table.row(&[
            label.into(),
            format!("{rel:.0e}"),
            format!("{:.3}", qai::metrics::bit_rate(stream.len(), orig.len())),
            format!("{s0:.4}"),
            format!("{s1:.4}"),
            format!("{:+.4}", s1 - s0),
            format!("{p0:.2}"),
            format!("{p1:.2}"),
            format!("{:+.2}", p1 - p0),
        ]);
    }
    table.print("Fig. 7: Hurricane case study (A low / B moderate / C very high EB)");

    let a = rows.iter().find(|r| r.0 == "A").unwrap();
    let b = rows.iter().find(|r| r.0 == "B").unwrap();
    let c = rows.iter().find(|r| r.0 == "C").unwrap();
    // A: no degradation, tiny change. B: clear gains. C: SSIM gain dominates.
    assert!(a.1 > -1e-3 && a.2 > -0.2, "point A must not degrade");
    assert!(b.1 > 0.005 && b.2 > 1.0, "point B must show clear SSIM+PSNR gains");
    assert!(c.1 > b.1, "point C SSIM gain should exceed B's (more artifacts to fix)");
    println!("\nfig7_case_study: OK (A ~neutral, B strong, C SSIM-dominant)");
}
