//! Open-loop load harness for the serving engine's SLO layer.
//!
//! Unlike the closed-loop `qai serve` subcommand (which retries
//! rejected submissions and therefore self-throttles), this harness
//! offers jobs on a **fixed arrival schedule** regardless of how the
//! engine keeps up — the methodology that actually reveals tail
//! latency and shed behavior under overload. The schedule is
//! deliberately infeasible (offered rate ≈ 1.5× the calibrated service
//! capacity), so all three admission-control outcomes occur: queue
//! backpressure, token-bucket quota rejections, and
//! deadline-infeasibility sheds.
//!
//! Results go to stdout and to `BENCH_serve.json` (throughput, p50/p99
//! total latency, queue-wait p99, shed breakdown) for the CI smoke
//! check. The file holds a JSON **array** of per-run records and every
//! run appends to it, so successive runs (and successive PRs, when the
//! file is kept around) form a throughput/latency trajectory rather
//! than a single overwritten sample; legacy single-object files are
//! wrapped into the array form on first append. Latency quantiles come
//! from the same log-bucketed [`LatencyHistogram`] the engine's
//! metrics surface uses, so a reported p99 is the bucket upper edge —
//! a conservative bound.

use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{self, Engine, MitigationRequest, ResponseTicket};
use qai::mitigation::{Job, SubmitError};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::hist::LatencyHistogram;
use std::time::{Duration, Instant};

const DIMS: &[usize] = &[32, 32];
const TENANTS: usize = 4;

fn make_job(seed: u64) -> Job {
    let orig = generate(DatasetKind::ClimateLike, DIMS, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    Job::new(dq, q, eb)
}

/// Median-of-several direct executions: the service-time estimate the
/// arrival schedule and deadlines are derived from.
fn calibrate(job: &Job) -> f64 {
    let mut samples: Vec<f64> = (0..5)
        .map(|_| {
            let t0 = Instant::now();
            engine::execute(&MitigationRequest::from_job(job.clone())).unwrap();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(|a, b| a.total_cmp(b));
    samples[samples.len() / 2].max(1e-6)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let offered_jobs: usize = if quick { 80 } else { 400 };
    let lanes = 2usize;

    // A small rotating working set: cloning a Job is an Arc bump, so
    // the harness measures the serving layer, not ingest.
    let inputs: Vec<Job> = (0..8).map(make_job).collect();
    let est_s = calibrate(&inputs[0]);

    // Offered rate ≈ 1.5× the engine's calibrated capacity; deadlines
    // at 20× the service time, so early jobs meet them easily and the
    // growing backlog pushes later ones into shedding territory.
    let interval = Duration::from_secs_f64(est_s / (1.5 * lanes as f64));
    let deadline = Duration::from_secs_f64(20.0 * est_s);

    let engine = Engine::builder()
        .shards(2)
        .capacity(64)
        .lanes_per_shard(lanes)
        .shed(true)
        .adaptive_lanes(true)
        .default_quota_rate(3.0 / est_s)
        .default_quota_burst(32)
        .build();

    let mut tickets: Vec<ResponseTicket> = Vec::with_capacity(offered_jobs);
    let mut shed_queue = 0usize;
    let mut shed_quota = 0usize;
    let mut shed_infeasible = 0usize;
    let t0 = Instant::now();
    for i in 0..offered_jobs {
        // Fixed schedule: job i is due at t0 + i·interval, no matter
        // what happened to earlier jobs.
        let due = t0 + interval * i as u32;
        loop {
            let now = Instant::now();
            match due.checked_duration_since(now) {
                Some(wait) if wait > Duration::from_micros(200) => std::thread::sleep(wait),
                Some(_) => std::hint::spin_loop(),
                None => break,
            }
        }
        let request = MitigationRequest::from_job(inputs[i % inputs.len()].clone())
            .tenant(format!("t{}", i % TENANTS))
            .deadline(deadline);
        match engine.try_submit(request) {
            Ok(ticket) => tickets.push(ticket),
            Err(SubmitError::QueueFull(_)) => shed_queue += 1,
            Err(SubmitError::QuotaExceeded(_)) => shed_quota += 1,
            Err(SubmitError::DeadlineInfeasible(_)) => shed_infeasible += 1,
            Err(e) => panic!("unexpected submit failure: {e}"),
        }
    }

    let mut total_hist = LatencyHistogram::new();
    let mut wait_hist = LatencyHistogram::new();
    let mut completed = 0usize;
    let mut failed = 0usize;
    let mut deadline_misses = 0usize;
    for ticket in tickets {
        match ticket.wait() {
            Ok(resp) => {
                completed += 1;
                total_hist.record(resp.queue_wait + resp.exec);
                wait_hist.record(resp.queue_wait);
                if resp.deadline_missed {
                    deadline_misses += 1;
                }
            }
            Err(_) => failed += 1,
        }
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let shed = shed_queue + shed_quota + shed_infeasible;
    let shed_rate = shed as f64 / offered_jobs as f64;
    let throughput = completed as f64 / wall_s.max(1e-12);
    let agg = engine.stats().aggregate();

    println!("serve_load: open-loop, {offered_jobs} jobs offered over {wall_s:.3}s");
    println!(
        "  calibrated service time {:.3} ms, interval {:.3} ms, deadline {:.1} ms",
        est_s * 1e3,
        interval.as_secs_f64() * 1e3,
        deadline.as_secs_f64() * 1e3
    );
    println!(
        "  completed {completed} ({throughput:.1} jobs/s), failed {failed}, \
         shed {shed} ({:.1}% — queue {shed_queue}, quota {shed_quota}, \
         infeasible {shed_infeasible})",
        shed_rate * 100.0
    );
    println!(
        "  latency p50 {:.3} ms, p99 {:.3} ms (queue-wait p99 {:.3} ms); \
         deadline misses {deadline_misses} (engine counted {})",
        total_hist.quantile_ms(0.50),
        total_hist.quantile_ms(0.99),
        wait_hist.quantile_ms(0.99),
        agg.deadlines_missed
    );
    println!(
        "  scheduler: wakeups {}, lanes grown {}, shrunk {}, shard sheds {}",
        agg.sched_wakeups, agg.lanes_grown, agg.lanes_shrunk, agg.shed_infeasible
    );

    let record = format!(
        "{{\n  \"bench\": \"serve_load\",\n  \"generator\": \"cargo bench --bench serve_load{}\",\n  \
         \"mode\": \"open-loop\",\n  \"offered_jobs\": {},\n  \"completed\": {},\n  \
         \"failed\": {},\n  \"wall_s\": {:.6},\n  \"throughput_jobs_per_s\": {:.3},\n  \
         \"p50_ms\": {:.3},\n  \"p99_ms\": {:.3},\n  \"wait_p99_ms\": {:.3},\n  \
         \"shed\": {},\n  \"shed_rate\": {:.6},\n  \"shed_queue_full\": {},\n  \
         \"shed_quota\": {},\n  \"shed_infeasible\": {},\n  \"deadline_misses\": {}\n}}",
        if quick { " -- --quick" } else { "" },
        offered_jobs,
        completed,
        failed,
        wall_s,
        throughput,
        total_hist.quantile_ms(0.50),
        total_hist.quantile_ms(0.99),
        wait_hist.quantile_ms(0.99),
        shed,
        shed_rate,
        shed_queue,
        shed_quota,
        shed_infeasible,
        deadline_misses,
    );
    println!();
    qai::bench_support::append_json_record("BENCH_serve.json", &record);
    println!("serve_load: OK");
}
