//! Fig. 6 — rate-distortion with PSNR: the paper's claim is that the
//! compensation improves SSIM *without degrading PSNR* (usually
//! improving it), while Gaussian/uniform filtering can cost many dB.

use qai::bench_support::rd::{method_value, sweep};
use qai::bench_support::tables::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let points = sweep(quick);

    let mut table = Table::new(&[
        "codec", "dataset", "rel_eb", "bits/val", "PSNR_q", "PSNR_gauss", "PSNR_unif",
        "PSNR_wien", "PSNR_ours", "dPSNR",
    ]);
    let mut big_drops = 0usize;
    let mut gauss_costly = 0usize;
    for p in &points {
        let q = method_value(p, "quantized", false);
        let ours = method_value(p, "ours", false);
        let gauss = method_value(p, "gaussian", false);
        if ours < q - 1.0 {
            big_drops += 1;
        }
        if gauss < q - 3.0 {
            gauss_costly += 1;
        }
        table.row(&[
            p.codec.into(),
            p.dataset.into(),
            format!("{:.0e}", p.rel_eb),
            format!("{:.3}", p.bit_rate),
            format!("{q:.2}"),
            format!("{gauss:.2}"),
            format!("{:.2}", method_value(p, "uniform", false)),
            format!("{:.2}", method_value(p, "wiener", false)),
            format!("{ours:.2}"),
            format!("{:+.2}", ours - q),
        ]);
    }
    table.print("Fig. 6: rate-distortion (PSNR, dB)");
    assert!(
        big_drops <= points.len() / 10,
        "ours dropped PSNR >1dB in {big_drops}/{} cells",
        points.len()
    );
    assert!(gauss_costly > 0, "expected Gaussian to cost >3dB somewhere (paper's shape)");
    println!(
        "\nours: {big_drops} cells with >1dB PSNR loss; gaussian: {gauss_costly} cells with >3dB loss"
    );
    println!("fig6_rd_psnr: OK");
}
