//! Fig. 11 — execution-time breakdown (computation vs communication) of
//! the Approximate strategy under weak scaling.
//!
//! Ported to real multi-process runs: the driver forks one `qai
//! rank-worker` per rank, ranks mesh over localhost TCP, and the
//! communication column is **measured** — per-rank nanoseconds spent
//! inside transport send/recv plus the transport's wire byte/message
//! counters — instead of the analytic `CommModel`. The paper reports
//! < 3% communication at 64–128 ranks rising with load imbalance; the
//! same shape (halo traffic a small share of the makespan) emerges here
//! at single-host process counts.

use qai::bench_support::tables::Table;
use qai::cluster::procs::run_distributed_procs;
use qai::coordinator::Strategy;
use qai::data::synthetic::{generate, DatasetKind};
use qai::quant::{quantize_grid, ErrorBound};
use std::path::Path;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let qai_bin = Path::new(env!("CARGO_BIN_EXE_qai"));
    let per_rank = 24usize;
    let rank_counts: &[usize] = if quick { &[2, 4] } else { &[2, 4, 8] };

    let mut table = Table::new(&[
        "procs", "domain", "wall(ms)", "comm_max(ms)", "comm_share(%)", "wire(KB)",
        "bytes/rank", "msgs",
    ]);
    let mut prev_bytes_per_rank = 0.0f64;
    for &ranks in rank_counts {
        let side = ((ranks as f64).cbrt() * per_rank as f64).round() as usize;
        let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 11);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let (_, rep) =
            run_distributed_procs(qai_bin, &dq, &q, eb, Strategy::Approximate, ranks, 0.9, 1)
                .unwrap();

        let share = rep.comm_fraction() * 100.0;
        let bytes_per_rank = rep.bytes as f64 / rep.ranks as f64;
        table.row(&[
            format!("{}", rep.ranks),
            format!("{side}^3"),
            format!("{:.2}", rep.wall_s * 1e3),
            format!("{:.4}", rep.comm_s * 1e3),
            format!("{share:.2}"),
            format!("{:.1}", rep.bytes as f64 / 1e3),
            format!("{bytes_per_rank:.0}"),
            format!("{}", rep.msgs),
        ]);
        // Deterministic invariants of the halo exchange, from the
        // measured counters: traffic exists, and under weak scaling the
        // per-rank halo volume does not shrink as faces are added.
        assert!(rep.bytes > 0 && rep.msgs > 0, "halo exchange must move wire bytes");
        assert!(
            bytes_per_rank >= prev_bytes_per_rank * 0.5,
            "per-rank halo volume collapsed: {bytes_per_rank:.0} after {prev_bytes_per_rank:.0}"
        );
        prev_bytes_per_rank = bytes_per_rank;
        assert!(share < 50.0, "halo comm should not dominate the approximate strategy");
    }
    table.print(
        "Fig. 11: computation vs communication breakdown \
         (Approximate, weak scaling, real processes, measured counters)",
    );
    println!("\nfig11_comm_breakdown: OK (measured stencil comm stays a small share of makespan)");
}
