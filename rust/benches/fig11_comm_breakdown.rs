//! Fig. 11 — execution-time breakdown (computation vs communication) of
//! the Approximate strategy under weak scaling. The paper reports < 3%
//! communication at 64–128 ranks, rising at 256 ranks with load
//! imbalance; the same shape emerges here from the measured per-rank
//! compute spread + modeled halo traffic.

use qai::bench_support::tables::Table;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let per_rank = 32usize;
    let rank_counts: &[usize] = if quick { &[8, 27] } else { &[8, 27, 64] };

    let mut table = Table::new(&[
        "ranks", "compute_max(ms)", "compute_min(ms)", "imbalance", "comm_modeled(ms)",
        "comm_share(%)", "halo_bytes/rank",
    ]);
    for &ranks in rank_counts {
        let side = (ranks as f64).cbrt().round() as usize * per_rank;
        let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 11);
        let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let cfg =
            DistributedConfig { ranks, strategy: Strategy::Approximate, ..Default::default() };
        let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();

        let cmax = rep.compute_s.iter().cloned().fold(0.0, f64::max);
        let cmin = rep.compute_s.iter().cloned().fold(f64::INFINITY, f64::min);
        let comm_max = rep.comm_s.iter().cloned().fold(0.0, f64::max);
        let share = rep.comm_fraction() * 100.0;
        table.row(&[
            format!("{}", rep.ranks),
            format!("{:.2}", cmax * 1e3),
            format!("{:.2}", cmin * 1e3),
            format!("{:.2}", cmax / cmin.max(1e-12)),
            format!("{:.4}", comm_max * 1e3),
            format!("{share:.2}"),
            format!("{:.0}", rep.total_bytes() as f64 / rep.ranks as f64),
        ]);
        assert!(share < 50.0, "halo comm should not dominate the approximate strategy");
    }
    table.print("Fig. 11: computation vs communication breakdown (Approximate, weak scaling)");
    println!("\nfig11_comm_breakdown: OK (stencil comm stays a small share of makespan)");
}
