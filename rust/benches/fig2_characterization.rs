//! Fig. 2 — characterization of pre-quantization artifacts on the
//! Miranda-analog density field: (1) clustering of quantization indices
//! into contoured regions, (2) error-sign flipping at quantization
//! boundaries correlated with the index gradient, (3) error magnitude
//! peaking (≈ ε) at boundaries and decaying toward region interiors.
//!
//! Regenerates the paper's three findings as tables + a 1D line cut.

use qai::bench_support::tables::Table;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::boundary::boundary_and_sign;
use qai::mitigation::edt::edt;
use qai::mitigation::sign::propagate_signs;
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let dims = [64, 128, 128];
    let orig = generate(DatasetKind::MirandaLike, &dims, 2);
    // The paper uses 5e-4 on 512³ Miranda; this 128-scale analog has ~4×
    // the per-cell gradient, so the banding-equivalent bound is ~5e-3
    // (DESIGN.md §5 resolution scaling).
    let rel = 5e-3;
    let eb = ErrorBound::relative(rel).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let bres = boundary_and_sign(&q, 1);
    let n = orig.len();

    // Finding 0: index clustering — boundary points are a minority and
    // indices form contiguous regions.
    let n_boundary = bres.mask.data.iter().filter(|&&b| b).count();
    println!(
        "index clustering: {} of {} points ({:.1}%) are quantization boundaries",
        n_boundary,
        n,
        n_boundary as f64 / n as f64 * 100.0
    );

    // Finding 1: sign at boundaries correlates with the index gradient.
    let mut agree = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        if bres.mask.data[i] && bres.sign.data[i] != 0 {
            let err = orig.data[i] as f64 - dq.data[i] as f64;
            if err != 0.0 {
                total += 1;
                if (err > 0.0) == (bres.sign.data[i] > 0) {
                    agree += 1;
                }
            }
        }
    }
    println!(
        "error-sign vs index-gradient agreement at boundaries: {:.1}% ({} samples)",
        agree as f64 / total.max(1) as f64 * 100.0,
        total
    );
    assert!(agree as f64 / total.max(1) as f64 > 0.8, "finding 1 does not reproduce");

    // Finding 2/3: |error| vs distance to the nearest boundary, in the
    // smooth (sign-carrying) regions where the characterization applies
    // (fast-varying regions are excluded by Alg. 2's gradient gate).
    let edt1 = edt(&bres.mask, true, 1);
    let (s, _b2) = propagate_signs(&bres.mask, &bres.sign, edt1.nearest.as_ref().unwrap(), 1);
    let mut bins = vec![(0.0f64, 0usize); 8];
    for i in 0..n {
        let d = edt1.dist(i);
        if !d.is_finite() || s.data[i] == 0 {
            continue;
        }
        let b = (d as usize).min(bins.len() - 1);
        bins[b].0 += (orig.data[i] as f64 - dq.data[i] as f64).abs();
        bins[b].1 += 1;
    }
    let mut table = Table::new(&["dist_to_boundary", "mean|err|/eps", "samples"]);
    let mut ratios = Vec::new();
    for (d, (sum, cnt)) in bins.iter().enumerate() {
        if *cnt == 0 {
            continue;
        }
        let ratio = sum / *cnt as f64 / eb.abs;
        table.row(&[format!("{d}"), format!("{ratio:.3}"), format!("{cnt}")]);
        ratios.push((d, ratio));
    }
    table.print("Fig. 2 finding 2/3: error magnitude vs distance to quantization boundary");
    // Error peaks near the boundary and decays away from it.
    let at0 = ratios.iter().find(|(d, _)| *d == 0).map(|(_, r)| *r).unwrap_or(0.0);
    let far = ratios
        .iter()
        .filter(|(d, _)| *d >= 3)
        .map(|(_, r)| *r)
        .fold(f64::NAN, |acc: f64, r| if acc.is_nan() { r } else { acc.min(r) });
    assert!(
        at0 > 0.5 && (far.is_nan() || far < at0),
        "boundary error should be near eps and decay: at0={at0:.3} far={far:.3}"
    );

    // Line cut (Fig. 2(c) analog).
    println!("\n1D line cut (i=32, j=64): original vs quantized, sign flips visible");
    println!("{:>4} {:>10} {:>10} {:>9} {:>5}", "k", "orig", "quantized", "err/eps", "q");
    for k in (30..62).step_by(2) {
        let o = orig.at(32, 64, k);
        let r = dq.at(32, 64, k);
        println!(
            "{:>4} {:>10.5} {:>10.5} {:>9.3} {:>5}",
            k,
            o,
            r,
            (o as f64 - r as f64) / eb.abs,
            q.at(32, 64, k)
        );
    }
    println!("\nfig2_characterization: OK");
}
