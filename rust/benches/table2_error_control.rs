//! Table II — maximum relative error after Gaussian / uniform / Wiener
//! filtering vs our compensation, at ε = 1e-3, against the relaxed
//! bound (1+η)ε = 1.9e-3. The paper's claim: smoothing filters can
//! violate the relaxed bound (by orders of magnitude near fronts),
//! Wiener usually behaves but has no guarantee, ours is *always* within.

use qai::bench_support::tables::Table;
use qai::compressors::{cusz::CuszLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::filters::{gaussian_filter, uniform_filter, wiener_filter};
use qai::metrics::max_rel_error;
use qai::mitigation::engine::{self, MitigationRequest};
use qai::quant::ErrorBound;

fn main() {
    let rel = 1e-3;
    let relaxed = 1.9e-3;
    let cases: Vec<(&str, DatasetKind, Vec<usize>, u64)> = vec![
        ("CESM/f0", DatasetKind::ClimateLike, vec![256, 512], 10),
        ("CESM/f1", DatasetKind::ClimateLike, vec![256, 512], 11),
        ("Hurricane/f0", DatasetKind::HurricaneLike, vec![50, 100, 100], 12),
        ("Hurricane/f1", DatasetKind::HurricaneLike, vec![50, 100, 100], 13),
        ("NYX/f0", DatasetKind::CosmologyLike, vec![64, 64, 64], 14),
        ("NYX/f1", DatasetKind::CosmologyLike, vec![64, 64, 64], 15),
        ("S3D/f0", DatasetKind::CombustionLike, vec![64, 64, 64], 16),
        ("S3D/f1", DatasetKind::CombustionLike, vec![64, 64, 64], 17),
    ];

    let mut table =
        Table::new(&["dataset/field", "Gaussian", "Uniform", "Wiener", "Ours", "ours<=1.9e-3"]);
    let mut any_filter_violates = false;
    for (name, kind, dims, seed) in cases {
        let orig = generate(kind, &dims, seed);
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let dec = CuszLike.decompress(&CuszLike.compress(&orig, eb).unwrap()).unwrap();

        let e_gauss = max_rel_error(&orig.data, &gaussian_filter(&dec.grid, 1.0).data);
        let e_unif = max_rel_error(&orig.data, &uniform_filter(&dec.grid).data);
        let e_wien = max_rel_error(&orig.data, &wiener_filter(&dec.grid, eb.abs).data);
        let request = MitigationRequest::new(dec.grid, dec.quant_indices, eb);
        let ours = engine::execute(&request).unwrap().output;
        let e_ours = max_rel_error(&orig.data, &ours.data);

        any_filter_violates |= e_gauss > relaxed || e_unif > relaxed;
        let ok = e_ours <= relaxed * (1.0 + 1e-5);
        assert!(ok, "{name}: ours violated the relaxed bound: {e_ours}");
        table.row(&[
            name.into(),
            format!("{e_gauss:.4}"),
            format!("{e_unif:.4}"),
            format!("{e_wien:.4}"),
            format!("{e_ours:.4}"),
            format!("{ok}"),
        ]);
    }
    table.print("Table II: maximum relative error after compensation (ε = 1e-3)");
    assert!(
        any_filter_violates,
        "expected at least one smoothing-filter violation of the relaxed bound"
    );
    println!("\ntable2_error_control: OK (ours always within (1+η)ε; smoothers violate)");
}
