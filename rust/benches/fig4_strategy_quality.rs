//! Fig. 4 — quality of the three distributed parallelization strategies
//! on a 64-core 3D decomposition: the embarrassingly-parallel variant
//! shows rank-boundary striping (lower SSIM, larger error near faces);
//! exact and approximate match the sequential result (approximate within
//! noise).

use qai::bench_support::tables::Table;
use qai::coordinator::topology::Topology;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let dims = [64, 64, 64];
    let orig = generate(DatasetKind::MirandaLike, &dims, 4);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let seq = engine::execute(&MitigationRequest::new(dq.clone(), q.clone(), eb))
        .unwrap()
        .output;

    // Identify cells within 2 of a rank face for the striping metric.
    let topo = Topology::new(64, orig.shape);
    let mut near_face = vec![false; orig.len()];
    for r in 0..topo.n_ranks() {
        let (lo, size) = topo.block(r);
        for i in lo[0]..lo[0] + size[0] {
            for j in lo[1]..lo[1] + size[1] {
                for k in lo[2]..lo[2] + size[2] {
                    let df = [
                        i - lo[0],
                        lo[0] + size[0] - 1 - i,
                        j - lo[1],
                        lo[1] + size[1] - 1 - j,
                        k - lo[2],
                        lo[2] + size[2] - 1 - k,
                    ];
                    if df.iter().any(|&d| d < 2) {
                        near_face[orig.shape.idx(i, j, k)] = true;
                    }
                }
            }
        }
    }
    let face_rmse = |out: &qai::Grid<f32>| {
        let mut s = 0.0f64;
        let mut c = 0usize;
        for i in 0..orig.len() {
            if near_face[i] {
                s += (orig.data[i] as f64 - out.data[i] as f64).powi(2);
                c += 1;
            }
        }
        (s / c as f64).sqrt() / eb.abs
    };

    let mut table = Table::new(&[
        "variant", "SSIM", "PSNR(dB)", "face_RMSE/eps", "bytes_on_fabric",
    ]);
    table.row(&[
        "sequential".into(),
        format!("{:.4}", ssim(&orig, &seq, 7, 2)),
        format!("{:.2}", psnr(&orig.data, &seq.data)),
        format!("{:.3}", face_rmse(&seq)),
        "-".into(),
    ]);
    table.row(&[
        "quantized (no mitigation)".into(),
        format!("{:.4}", ssim(&orig, &dq, 7, 2)),
        format!("{:.2}", psnr(&orig.data, &dq.data)),
        format!("{:.3}", face_rmse(&dq)),
        "-".into(),
    ]);

    let mut results = Vec::new();
    for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
        let cfg = DistributedConfig { ranks: 64, strategy, ..Default::default() };
        let (out, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        let s = ssim(&orig, &out, 7, 2);
        results.push((strategy, s, face_rmse(&out)));
        table.row(&[
            strategy.name().into(),
            format!("{s:.4}"),
            format!("{:.2}", psnr(&orig.data, &out.data)),
            format!("{:.3}", face_rmse(&out)),
            format!("{}", rep.total_bytes()),
        ]);
    }
    table.print("Fig. 4: error quality of the three parallel strategies (64 ranks)");

    let embar = results.iter().find(|r| r.0 == Strategy::Embarrassing).unwrap();
    let exact = results.iter().find(|r| r.0 == Strategy::Exact).unwrap();
    let approx = results.iter().find(|r| r.0 == Strategy::Approximate).unwrap();
    assert!(exact.1 >= approx.1 - 1e-9, "exact SSIM below approximate");
    assert!(approx.1 >= embar.1, "approximate SSIM below embarrassing");
    assert!(
        embar.2 >= approx.2,
        "embarrassing should have worse face error (striping): {} vs {}",
        embar.2,
        approx.2
    );
    println!("\nfig4_strategy_quality: OK (striping visible in Embarrassingly Parallel)");
}
