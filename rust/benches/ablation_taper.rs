//! Ablation of the homogeneous-region taper (paper §IX future work,
//! implemented as `MitigationConfig::taper_radius`): on fields with
//! large uniform-index regions (hard-saturated climate data), the
//! published algorithm compensates deep inside homogeneous zones where
//! there is no boundary structure to reconstruct; the taper suppresses
//! that, trading a little PSNR in banded zones for robustness in flat
//! ones. On fields without big homogeneous regions the taper should be
//! ~neutral at generous radii.

use qai::bench_support::tables::Table;
use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{max_rel_error, psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::MitigationConfig;
use qai::quant::{quantize_grid, ErrorBound};
use qai::SharedGrid;

/// A CESM-like field with *hard* saturation (exactly-flat plateaus) —
/// the paper's known-limitation regime.
fn hard_clamped_climate(dims: &[usize], seed: u64) -> Grid<f32> {
    let mut g = generate(DatasetKind::ClimateLike, dims, seed);
    for v in g.data.iter_mut() {
        // re-saturate: everything in the outer 20% bands flattens
        *v = (*v).clamp(0.2, 0.8);
    }
    g
}

fn main() {
    // The same grid the engine's quality-target search sweeps (index 0
    // must stay `None`: the "no taper" row is the baseline below).
    let radii = qai::mitigation::quality::TAPER_CANDIDATES;
    let cases: Vec<(&str, Grid<f32>)> = vec![
        ("CESM-hard-clamped", hard_clamped_climate(&[256, 256], 3)),
        ("Miranda (banded)", generate(DatasetKind::MirandaLike, &[64, 64, 64], 3)),
    ];
    let rel = 1e-2;

    for (name, orig) in cases {
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        // Shared handles: per-radius request clones are pointer bumps.
        let dq: SharedGrid<f32> = dq.into();
        let q: SharedGrid<i64> = q.into();
        let s_dq = ssim(&orig, &dq, 7, 2);
        let p_dq = psnr(&orig.data, &dq.data);

        let mut table = Table::new(&["taper_radius", "SSIM", "PSNR(dB)", "max_rel_err"]);
        table.row(&[
            "(quantized)".into(),
            format!("{s_dq:.4}"),
            format!("{p_dq:.2}"),
            format!("{:.5}", max_rel_error(&orig.data, &dq.data)),
        ]);
        let mut results = Vec::new();
        for r in radii {
            let cfg = MitigationConfig { taper_radius: r, ..Default::default() };
            let request = MitigationRequest::new(dq.clone(), q.clone(), eb).config(cfg);
            let out = engine::execute(&request).unwrap().output;
            let s = ssim(&orig, &out, 7, 2);
            let p = psnr(&orig.data, &out.data);
            results.push((r, s, p));
            table.row(&[
                r.map(|x| format!("{x:.0}")).unwrap_or_else(|| "none (paper)".into()),
                format!("{s:.4}"),
                format!("{p:.2}"),
                format!("{:.5}", max_rel_error(&orig.data, &out.data)),
            ]);
        }
        table.print(&format!("taper ablation on {name} (ε = {rel:.0e})"));

        let none = results[0];
        let tapered_best =
            results[1..].iter().cloned().fold((None, f64::NEG_INFINITY, 0.0), |acc, x| {
                if x.1 > acc.1 {
                    x
                } else {
                    acc
                }
            });
        if name.contains("hard-clamped") {
            assert!(
                tapered_best.1 >= none.1,
                "taper should help (or tie) on hard-clamped data: {:.4} vs {:.4}",
                tapered_best.1,
                none.1
            );
        } else {
            // On banded data a generous radius must be near-neutral.
            let generous = results[1];
            assert!(
                (generous.1 - none.1).abs() < 0.005,
                "generous taper should be neutral on banded data"
            );
        }
    }
    println!("\nablation_taper: OK");
}
