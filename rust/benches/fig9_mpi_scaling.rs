//! Fig. 9 — distributed-memory scaling of the three strategies, weak
//! and strong, on the JHTDB-analog turbulence field.
//!
//! Substitution note (DESIGN.md §5): ranks are simulated on this host;
//! per-rank compute is measured as thread CPU time and communication is
//! modeled from the recorded per-message traffic (α+β·bytes with
//! intra-node discount). Throughput = bytes / (slowest rank's compute +
//! its modeled comm) — the paper's barrier-synchronized makespan. The
//! Exact strategy additionally serializes the global EDT on the leader,
//! which is what destroys its scaling, exactly as in the paper.

use qai::bench_support::tables::Table;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let strategies = [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate];

    // ---- Weak scaling: 32³ per rank (scaled from the paper's 512³). --
    let per_rank = 32usize;
    let rank_counts: &[usize] = if quick { &[8, 27] } else { &[8, 27, 64] };
    let mut table = Table::new(&[
        "strategy", "ranks", "domain", "thr(MB/s)", "efficiency", "comm(KB)",
    ]);
    let mut weak_eff: Vec<(Strategy, f64)> = Vec::new();
    for &strategy in &strategies {
        let mut base_per_rank_thr = 0.0f64;
        for &ranks in rank_counts {
            let side = (ranks as f64).cbrt().round() as usize * per_rank;
            let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 77);
            let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
            let (q, dq) = quantize_grid(&orig, eb);
            let cfg = DistributedConfig { ranks, strategy, ..Default::default() };
            let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            let thr = rep.modeled_throughput_mbs(orig.len());
            let per_rank_thr = thr / rep.ranks as f64;
            if ranks == rank_counts[0] {
                base_per_rank_thr = per_rank_thr;
            }
            let eff = per_rank_thr / base_per_rank_thr;
            if ranks == *rank_counts.last().unwrap() {
                weak_eff.push((strategy, eff));
            }
            table.row(&[
                strategy.name().into(),
                format!("{}", rep.ranks),
                format!("{side}^3"),
                format!("{thr:.1}"),
                format!("{eff:.3}"),
                format!("{:.1}", rep.total_bytes() as f64 / 1e3),
            ]);
        }
    }
    table.print("Fig. 9a: weak scaling (32³ per rank)");

    // ---- Strong scaling: fixed domain split over more ranks. ---------
    let side = if quick { 64 } else { 96 };
    let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 78);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let mut table = Table::new(&["strategy", "ranks", "thr(MB/s)", "speedup", "efficiency"]);
    for &strategy in &strategies {
        let mut base_thr = 0.0f64;
        for &ranks in rank_counts {
            let cfg = DistributedConfig { ranks, strategy, ..Default::default() };
            let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            let thr = rep.modeled_throughput_mbs(orig.len());
            if ranks == rank_counts[0] {
                base_thr = thr;
            }
            let speedup = thr / base_thr;
            let eff = speedup / (ranks as f64 / rank_counts[0] as f64);
            table.row(&[
                strategy.name().into(),
                format!("{}", rep.ranks),
                format!("{thr:.1}"),
                format!("{speedup:.2}"),
                format!("{eff:.3}"),
            ]);
        }
    }
    table.print(&format!("Fig. 9b: strong scaling ({side}³ total)"));

    // Shape check: Exact scales worst in weak scaling.
    let eff_exact = weak_eff.iter().find(|x| x.0 == Strategy::Exact).unwrap().1;
    let eff_embar = weak_eff.iter().find(|x| x.0 == Strategy::Embarrassing).unwrap().1;
    let eff_approx = weak_eff.iter().find(|x| x.0 == Strategy::Approximate).unwrap().1;
    assert!(
        eff_exact < eff_embar && eff_exact < eff_approx,
        "exact must scale worst: exact={eff_exact:.3} embar={eff_embar:.3} approx={eff_approx:.3}"
    );
    println!("\nfig9_mpi_scaling: OK (Exact scales worst, Embarrassing/Approximate near-flat)");
}
