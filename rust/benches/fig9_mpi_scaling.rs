//! Fig. 9 — distributed-memory scaling of the three strategies, weak
//! and strong, on the JHTDB-analog turbulence field.
//!
//! Two tiers (DESIGN.md §5):
//!
//! * **Real multi-process runs** — the driver forks one `qai
//!   rank-worker` process per rank; ranks form a TCP mesh over
//!   localhost and exchange halos/gathers over real sockets
//!   ([`run_distributed_procs`]). Throughput and communication are
//!   *measured* (wall clock + transport byte counters). Rank counts are
//!   bounded by what one host can fork.
//! * **Modeled high-rank runs** — the in-process fabric simulation
//!   (α+β·bytes comm model) extends the curves to the paper's 27–64
//!   rank regime where forking real processes is not meaningful on a
//!   single machine.
//!
//! The Exact strategy serializes the global EDT on the leader, which is
//! what destroys its scaling, exactly as in the paper. The shape checks
//! assert the deterministic part of that story — the communication-
//! volume ordering exact ≫ approximate > embarrassing (= 0) from the
//! measured wire counters — rather than host-dependent timings.

use qai::bench_support::tables::Table;
use qai::cluster::procs::run_distributed_procs;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::quant::{quantize_grid, ErrorBound};
use std::path::Path;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let qai_bin = Path::new(env!("CARGO_BIN_EXE_qai"));
    let strategies = [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate];

    // ---- Real processes, weak scaling: ~24³ per rank. ----------------
    let per_rank = 24usize;
    let proc_counts: &[usize] = if quick { &[1, 2] } else { &[1, 2, 4, 8] };
    let mut table = Table::new(&[
        "strategy", "procs", "domain", "thr(MB/s)", "efficiency", "wire(KB)",
    ]);
    let mut wire_at_max: Vec<(Strategy, u64)> = Vec::new();
    for &strategy in &strategies {
        let mut base_per_rank_thr = 0.0f64;
        for &ranks in proc_counts {
            let side = ((ranks as f64).cbrt() * per_rank as f64).round() as usize;
            let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 77);
            let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
            let (q, dq) = quantize_grid(&orig, eb);
            let (_, rep) =
                run_distributed_procs(qai_bin, &dq, &q, eb, strategy, ranks, 0.9, 1).unwrap();
            let thr = rep.throughput_mbs();
            let per_rank_thr = thr / rep.ranks as f64;
            if ranks == proc_counts[0] {
                base_per_rank_thr = per_rank_thr;
            }
            let eff = per_rank_thr / base_per_rank_thr.max(1e-12);
            if ranks == *proc_counts.last().unwrap() {
                wire_at_max.push((strategy, rep.bytes));
            }
            table.row(&[
                strategy.name().into(),
                format!("{}", rep.ranks),
                format!("{side}^3"),
                format!("{thr:.1}"),
                format!("{eff:.3}"),
                format!("{:.1}", rep.bytes as f64 / 1e3),
            ]);
        }
    }
    table.print("Fig. 9a: weak scaling, real processes (~24³ per rank, measured)");

    // ---- Real processes, strong scaling: fixed domain. ---------------
    let side = if quick { 32 } else { 48 };
    let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 78);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    let mut table = Table::new(&["strategy", "procs", "thr(MB/s)", "speedup", "efficiency"]);
    for &strategy in &strategies {
        let mut base_thr = 0.0f64;
        for &ranks in proc_counts {
            let (_, rep) =
                run_distributed_procs(qai_bin, &dq, &q, eb, strategy, ranks, 0.9, 1).unwrap();
            let thr = rep.throughput_mbs();
            if ranks == proc_counts[0] {
                base_thr = thr;
            }
            let speedup = thr / base_thr.max(1e-12);
            let eff = speedup / (ranks as f64 / proc_counts[0] as f64);
            table.row(&[
                strategy.name().into(),
                format!("{}", rep.ranks),
                format!("{thr:.1}"),
                format!("{speedup:.2}"),
                format!("{eff:.3}"),
            ]);
        }
    }
    table.print(&format!("Fig. 9b: strong scaling, real processes ({side}³ total, measured)"));

    // ---- Modeled extension to the paper's rank counts. ---------------
    let rank_counts: &[usize] = if quick { &[8, 27] } else { &[8, 27, 64] };
    let mut table = Table::new(&["strategy", "ranks", "domain", "thr(MB/s)", "efficiency"]);
    let mut weak_eff: Vec<(Strategy, f64)> = Vec::new();
    for &strategy in &strategies {
        let mut base_per_rank_thr = 0.0f64;
        for &ranks in rank_counts {
            let side = (ranks as f64).cbrt().round() as usize * 32;
            let orig = generate(DatasetKind::TurbulenceLike, &[side, side, side], 77);
            let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
            let (q, dq) = quantize_grid(&orig, eb);
            let cfg = DistributedConfig { ranks, strategy, ..Default::default() };
            let (_, rep) = run_distributed(&dq, &q, eb, &cfg).unwrap();
            let thr = rep.modeled_throughput_mbs(orig.len());
            let per_rank_thr = thr / rep.ranks as f64;
            if ranks == rank_counts[0] {
                base_per_rank_thr = per_rank_thr;
            }
            let eff = per_rank_thr / base_per_rank_thr;
            if ranks == *rank_counts.last().unwrap() {
                weak_eff.push((strategy, eff));
            }
            table.row(&[
                strategy.name().into(),
                format!("{}", rep.ranks),
                format!("{side}^3"),
                format!("{thr:.1}"),
                format!("{eff:.3}"),
            ]);
        }
    }
    table.print("Fig. 9c: weak scaling, modeled fabric (32³ per rank, paper rank counts)");

    // Shape checks. Deterministic tier first: the measured wire volume
    // at the largest real process count must order exact ≫ approximate
    // > embarrassing (= 0) — the mechanism behind the paper's scaling
    // gap, independent of host timing noise.
    let wire = |s: Strategy| wire_at_max.iter().find(|x| x.0 == s).unwrap().1;
    assert_eq!(wire(Strategy::Embarrassing), 0, "embarrassing must move zero bytes");
    assert!(wire(Strategy::Approximate) > 0, "approximate must exchange halos");
    assert!(
        wire(Strategy::Exact) > wire(Strategy::Approximate),
        "exact gather/scatter must dwarf halo traffic: exact={} approx={}",
        wire(Strategy::Exact),
        wire(Strategy::Approximate)
    );
    // Modeled tier: Exact scales worst in weak scaling.
    let eff_exact = weak_eff.iter().find(|x| x.0 == Strategy::Exact).unwrap().1;
    let eff_embar = weak_eff.iter().find(|x| x.0 == Strategy::Embarrassing).unwrap().1;
    let eff_approx = weak_eff.iter().find(|x| x.0 == Strategy::Approximate).unwrap().1;
    assert!(
        eff_exact < eff_embar && eff_exact < eff_approx,
        "exact must scale worst: exact={eff_exact:.3} embar={eff_embar:.3} approx={eff_approx:.3}"
    );
    println!(
        "\nfig9_mpi_scaling: OK (measured wire volume exact >> approx > embar=0; \
         modeled Exact scales worst)"
    );
}
