//! Fig. 8 — shared-memory efficiency: our mitigation pipeline vs the
//! SZp-like and SZ3-like decompression, swept over thread counts at
//! ε = 1e-3 on the four small-scale datasets.
//!
//! Host note (DESIGN.md §5): this machine exposes a single core, so
//! wall-clock speedup saturates at ~1. We therefore report, alongside
//! wall time, the *CPU-time inflation* `cpu(t_n)/cpu(t_1)` — the
//! parallelization overhead that, on a real multicore node, is exactly
//! what separates the measured efficiency curve from the ideal 1.0 line
//! (the paper's Fig. 8 efficiency = speedup/threads = 1/inflation when
//! cores are not oversubscribed).

use qai::bench_support::tables::Table;
use qai::compressors::{sz3::Sz3Like, szp::SzpLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::pipeline::MitigationConfig;
use qai::quant::ErrorBound;
use qai::util::pool::ThreadPool;
use qai::util::timer::thread_cpu_time;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Minimal fork-join `for_range` (fresh scoped threads per call,
/// self-scheduled over `grain`-sized batches) — the dispatch baseline
/// the work-stealing pool is compared against in the addendum table.
fn forkjoin_for_range<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    let grain = grain.max(1);
    if threads <= 1 || n <= grain {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n.div_ceil(grain)) {
            let next = &next;
            let fr = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + grain).min(n) {
                    fr(i);
                }
            });
        }
    });
}

fn cpu_time<F: FnMut()>(mut f: F) -> f64 {
    // run on a fresh thread so CLOCK_THREAD_CPUTIME_ID scopes exactly
    // this workload's serial section (workers are self-timed anyway —
    // the inflation metric is about total work, so sum via process time)
    let t0 = cpu_process_time();
    f();
    cpu_process_time() - t0
}

fn cpu_process_time() -> f64 {
    let mut ts = libc::timespec { tv_sec: 0, tv_nsec: 0 };
    unsafe { libc::clock_gettime(libc::CLOCK_PROCESS_CPUTIME_ID, &mut ts) };
    ts.tv_sec as f64 + ts.tv_nsec as f64 * 1e-9
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads_sweep: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let cases: Vec<(DatasetKind, Vec<usize>)> = vec![
        (DatasetKind::ClimateLike, vec![512, 512]),
        (DatasetKind::HurricaneLike, vec![50, 100, 100]),
        (DatasetKind::CosmologyLike, vec![64, 64, 64]),
        (DatasetKind::CombustionLike, vec![64, 64, 64]),
    ];
    let _ = thread_cpu_time(); // keep linkage of the per-thread clock used elsewhere

    let mut table = Table::new(&[
        "dataset", "system", "threads", "cpu_time(ms)", "inflation", "est_efficiency",
    ]);
    for (kind, dims) in cases {
        let orig = generate(kind, &dims, 30);
        let eb = ErrorBound::relative(1e-3).resolve(&orig.data);

        // Ours: the mitigation pipeline.
        let (q, dq) = qai::quant::quantize_grid(&orig, eb);
        let dq: qai::SharedGrid<f32> = dq.into();
        let q: qai::SharedGrid<i64> = q.into();
        let mut base_cpu = 0.0;
        for &t in threads_sweep {
            let cfg = MitigationConfig { threads: t, ..Default::default() };
            let request = MitigationRequest::new(dq.clone(), q.clone(), eb).config(cfg);
            let cpu = cpu_time(|| {
                let _ = engine::execute(&request).unwrap();
            });
            if t == 1 {
                base_cpu = cpu;
            }
            let inflation = cpu / base_cpu;
            table.row(&[
                kind.paper_name().into(),
                "QAI mitigation".into(),
                format!("{t}"),
                format!("{:.1}", cpu * 1e3),
                format!("{inflation:.3}"),
                format!("{:.3}", 1.0 / inflation),
            ]);
        }

        // SZp-like decompression.
        let szp_stream = SzpLike::default().compress(&orig, eb).unwrap();
        let mut base_cpu = 0.0;
        for &t in threads_sweep {
            let codec = SzpLike { threads: t };
            let cpu = cpu_time(|| {
                let _ = codec.decompress(&szp_stream).unwrap();
            });
            if t == 1 {
                base_cpu = cpu;
            }
            let inflation = cpu / base_cpu;
            table.row(&[
                kind.paper_name().into(),
                "SZp decompression".into(),
                format!("{t}"),
                format!("{:.1}", cpu * 1e3),
                format!("{inflation:.3}"),
                format!("{:.3}", 1.0 / inflation),
            ]);
        }

        // SZ3-like decompression.
        let sz3_stream = Sz3Like::default().compress(&orig, eb).unwrap();
        let mut base_cpu = 0.0;
        for &t in threads_sweep {
            let codec = Sz3Like { threads: t };
            let cpu = cpu_time(|| {
                let _ = codec.decompress(&sz3_stream).unwrap();
            });
            if t == 1 {
                base_cpu = cpu;
            }
            let inflation = cpu / base_cpu;
            table.row(&[
                kind.paper_name().into(),
                "SZ3 decompression".into(),
                format!("{t}"),
                format!("{:.1}", cpu * 1e3),
                format!("{inflation:.3}"),
                format!("{:.3}", 1.0 / inflation),
            ]);
        }
    }
    table.print("Fig. 8: shared-memory efficiency (ε = 1e-3; 1-core host → CPU-time inflation)");

    // ROADMAP follow-up: the ThreadPool-aware column — CPU-time
    // inflation of the *dispatch substrate itself* on a fixed synthetic
    // kernel, persistent work-stealing pool vs fork-join (fresh scoped
    // threads per region). The kernel is identical on both sides, so
    // the inflation delta is pure scheduler overhead — what separates
    // the measured Fig. 8 efficiency curve from the ideal line once
    // per-region spawn costs are gone.
    let mut dispatch = Table::new(&[
        "threads",
        "pool cpu(ms)",
        "pool inflation",
        "fork-join cpu(ms)",
        "fork-join inflation",
    ]);
    let pool = ThreadPool::new(*threads_sweep.iter().max().unwrap());
    let kernel_n = 1usize << 17;
    let kernel = |i: usize| {
        std::hint::black_box((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 7);
    };
    let reps = if quick { 8 } else { 24 };
    let mut pool_base = 0.0_f64;
    let mut fj_base = 0.0_f64;
    for &t in threads_sweep {
        let pool_cpu = cpu_time(|| {
            for _ in 0..reps {
                pool.for_range(kernel_n, t, 1024, kernel);
            }
        });
        let fj_cpu = cpu_time(|| {
            for _ in 0..reps {
                forkjoin_for_range(kernel_n, t, 1024, kernel);
            }
        });
        if t == 1 {
            pool_base = pool_cpu;
            fj_base = fj_cpu;
        }
        dispatch.row(&[
            format!("{t}"),
            format!("{:.2}", pool_cpu * 1e3),
            format!("{:.3}", pool_cpu / pool_base.max(1e-12)),
            format!("{:.2}", fj_cpu * 1e3),
            format!("{:.3}", fj_cpu / fj_base.max(1e-12)),
        ]);
    }
    dispatch.print("Fig. 8 addendum: dispatch-substrate CPU inflation (work-stealing pool vs fork-join)");
    let c = pool.counters();
    println!(
        "pool scheduler counters: local_hits={} injector_pops={} steals={} help_runs={}",
        c.local_hits, c.injector_pops, c.steals, c.help_runs
    );

    println!("\nfig8_openmp_efficiency: OK");
}
