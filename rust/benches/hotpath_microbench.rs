//! Hot-path microbenchmarks — the §Perf driver (DESIGN.md §9).
//!
//! Times each pipeline stage (A–E), the end-to-end pipeline, the EDT in
//! isolation, the compressor codecs, and SSIM, on a 128³ block; prints
//! MB/s so before/after optimization deltas are directly comparable
//! (EXPERIMENTS.md §Perf records the iteration log). Also compares the
//! persistent pool runtime against the legacy fork-join primitives
//! (dispatch overhead + small-grid mitigation latency) and times the
//! batched mitigation service.

use qai::bench_support::harness::bench_fn;
use qai::compressors::{cusz::CuszLike, cuszp::CuszpLike, szp::SzpLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{ssim, ssim_fast, ssim_fast_on};
use qai::mitigation::boundary::boundary_and_sign;
use qai::mitigation::edt::edt;
use qai::mitigation::engine::{self, Engine, MitigationRequest};
use qai::mitigation::interpolate::compensate;
use qai::mitigation::pipeline::MitigationConfig;
use qai::mitigation::sign::propagate_signs;
use qai::mitigation::tiled::{run_tiled_szp, TiledConfig};
use qai::quant::{quantize_grid, ErrorBound};
use qai::util::arena::{Arena, ArenaHandle};
use qai::util::pool::{self, PoolHandle};
use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};

/// Minimal copy of the retired `util::par` fork-join primitive, kept
/// here (and only here) as the dispatch-overhead baseline the pool
/// runtime is compared against: fresh `std::thread::scope` threads on
/// every call, self-scheduled over `grain`-sized batches.
fn forkjoin_for_batches<F>(n: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(std::ops::Range<usize>) + Sync,
{
    let grain = grain.max(1);
    if threads <= 1 || n <= grain {
        if n > 0 {
            f(0..n);
        }
        return;
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads.min(n.div_ceil(grain)) {
            let next = &next;
            let fr = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                fr(start..(start + grain).min(n));
            });
        }
    });
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let side = if quick { 64 } else { 128 };
    let (warm, samp) = if quick { (1, 3) } else { (2, 5) };
    let dims = [side, side, side];
    let n = side * side * side;
    let bytes = n * 4;

    let orig = generate(DatasetKind::MirandaLike, &dims, 1);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);

    println!("== stage timings on {side}^3 ({:.1} MB) ==", bytes as f64 / 1e6);
    let r = bench_fn("A: boundary_and_sign", warm, samp, || boundary_and_sign(&q, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let bres = boundary_and_sign(&q, 1);
    let r = bench_fn("B: EDT (with features)", warm, samp, || edt(&bres.mask, true, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let e1 = edt(&bres.mask, true, 1);
    let nearest = e1.nearest.as_ref().unwrap();
    let r = bench_fn("C: propagate_signs + B2", warm, samp, || {
        propagate_signs(&bres.mask, &bres.sign, nearest, 1)
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let (s, b2) = propagate_signs(&bres.mask, &bres.sign, nearest, 1);
    let r = bench_fn("D: EDT (no features)", warm, samp, || edt(&b2, false, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let e2 = edt(&b2, false, 1);
    let r = bench_fn("E: compensate", warm, samp, || {
        let mut data = dq.data.clone();
        compensate(&mut data, &e1.dist_sq, &e2.dist_sq, &s.data, 0.9 * eb.abs, 1);
        data
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let e2e_request = MitigationRequest::new(dq.clone(), q.clone(), eb).with_stats(true);
    let r = bench_fn("pipeline end-to-end", warm, samp, || {
        engine::execute(&e2e_request).unwrap()
    });
    println!("   -> {:.1} MB/s (paper §Perf target: >= ~10 MB/s/rank class)", r.mbs(bytes));

    println!("\n== substrate timings ==");
    let r = bench_fn("cuSZ-like compress", warm, samp, || CuszLike.compress(&orig, eb).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream = CuszLike.compress(&orig, eb).unwrap();
    let r = bench_fn("cuSZ-like decompress", warm, samp, || CuszLike.decompress(&stream).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let r = bench_fn("cuSZp2-like compress", warm, samp, || CuszpLike.compress(&orig, eb).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream_p = CuszpLike.compress(&orig, eb).unwrap();
    let r =
        bench_fn("cuSZp2-like decompress", warm, samp, || CuszpLike.decompress(&stream_p).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream_s = SzpLike::default().compress(&orig, eb).unwrap();
    let r = bench_fn("SZp-like decompress", warm, samp, || {
        SzpLike::default().decompress(&stream_s).unwrap()
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let dec = CuszLike.decompress(&stream).unwrap();
    let r = bench_fn("SSIM (w=7, s=2)", warm, samp, || ssim(&orig, &dec.grid, 7, 2));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    // Fused pooled SSIM vs the reference kernel: same boxed-window
    // score (bit-identical — the exactness matrix in tests/quality.rs
    // pins it), fewer full-grid buffers, and parallel axis passes.
    // Serial first (pure kernel delta), then on a 4-lane pool with a
    // warm arena (the serving-path configuration).
    let r = bench_fn("SSIM fused (w=7, s=2, serial)", warm, samp, || {
        ssim_fast(&orig, &dec.grid, 7, 2)
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let ssim_pool = pool::ThreadPool::new(4);
    let ssim_arena = Arena::new();
    let r = bench_fn("SSIM fused (w=7, s=2, pool x4 + arena)", warm, samp, || {
        ssim_fast_on(
            PoolHandle::Explicit(&ssim_pool),
            ArenaHandle::Pooled(&ssim_arena),
            &orig,
            &dec.grid,
            7,
            2,
            4,
        )
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    // Pool runtime vs the seed's fork-join primitives: identical work
    // decomposition and an explicit 4-lane pool (so both sides really
    // use 4-way parallelism regardless of host size) — the delta is
    // pure dispatch overhead (the cost mitigate() used to pay 5+ times
    // per call).
    println!("\n== pool runtime vs fork-join dispatch (4 threads) ==");
    let pool_threads = 4usize;
    let bench_pool = pool::ThreadPool::new(pool_threads);
    for &(lines, grain) in &[(64usize, 1usize), (4096, 16)] {
        let sink = AtomicU64::new(0);
        let r = bench_fn(
            &format!("pool for_batches ({lines} items, grain {grain})"),
            warm.max(2),
            samp.max(5),
            || {
                bench_pool.for_batches(lines, pool_threads, grain, |range| {
                    sink.fetch_add(range.len() as u64, Ordering::Relaxed);
                });
            },
        );
        let pool_mean = r.mean;
        let r = bench_fn(
            &format!("fork-join for_batches ({lines} items, grain {grain})"),
            warm.max(2),
            samp.max(5),
            || {
                forkjoin_for_batches(lines, pool_threads, grain, |range| {
                    sink.fetch_add(range.len() as u64, Ordering::Relaxed);
                });
            },
        );
        println!(
            "   -> pool dispatch {:.2}x fork-join ({:.1} us vs {:.1} us)",
            pool_mean / r.mean.max(1e-12),
            pool_mean * 1e6,
            r.mean * 1e6
        );
        black_box(sink.load(Ordering::Relaxed));
    }

    // Work-stealing observability: regions opened from *inside* a
    // worker publish their tickets on that worker's local deque, so
    // idle workers must steal to participate — the deep-nesting shape
    // the per-worker LIFO deques exist for. The counter deltas prove
    // the scheduler actually behaves that way under load.
    {
        let c0 = bench_pool.counters();
        let sink = AtomicU64::new(0);
        let r = bench_fn("nested regions (64 outer x 4096 inner)", warm.max(2), samp.max(5), || {
            bench_pool.for_range(64, pool_threads, 1, |o| {
                bench_pool.for_range(4096, pool_threads, 64, |i| {
                    sink.fetch_add((o + i) as u64, Ordering::Relaxed);
                });
            });
        });
        let c1 = bench_pool.counters();
        println!(
            "   -> {:.1} us/outer-region; scheduler deltas: +{} local_hits, +{} injector_pops, +{} steals, +{} help_runs",
            r.mean * 1e6 / 64.0,
            c1.local_hits - c0.local_hits,
            c1.injector_pops - c0.injector_pops,
            c1.steals - c0.steals,
            c1.help_runs - c0.help_runs,
        );
        black_box(sink.load(Ordering::Relaxed));
    }

    // Small-grid mitigation latency: per-step dispatch overhead
    // dominates here, which is exactly what the persistent pool removes
    // (acceptance: improved <= 64^3 latency vs the seed fork-join).
    println!("\n== small-grid threaded mitigation latency (threads = 4, pool) ==");
    for small in [32usize, 48, 64] {
        let sdims = [small, small, small];
        let sorig = generate(DatasetKind::MirandaLike, &sdims, 2);
        let seb = ErrorBound::relative(1e-2).resolve(&sorig.data);
        let (sq, sdq) = quantize_grid(&sorig, seb);
        let cfg = MitigationConfig { threads: 4, ..Default::default() };
        let request = MitigationRequest::new(sdq, sq, seb).config(cfg).with_stats(true);
        let r = bench_fn(&format!("mitigate {small}^3 (threads=4)"), warm, samp, || {
            engine::execute(&request).unwrap()
        });
        println!("   -> {:.1} MB/s", r.mbs(small * small * small * 4));
    }

    // Scratch-buffer arena: the same mitigation with every full-grid
    // buffer recycled vs allocated fresh per call. The delta is pure
    // allocator traffic — the cost a warm serving path no longer pays.
    println!("\n== arena scratch reuse vs fresh alloc (mitigate 64^3, threads = 1) ==");
    {
        let adims = [64usize; 3];
        let aorig = generate(DatasetKind::MirandaLike, &adims, 3);
        let aeb = ErrorBound::relative(1e-2).resolve(&aorig.data);
        let (aq, adq) = quantize_grid(&aorig, aeb);
        let abytes = adims.iter().product::<usize>() * 4;
        let arena_request = MitigationRequest::new(adq, aq, aeb).with_stats(true);
        let r = bench_fn("fresh-alloc mitigate", warm, samp, || {
            engine::execute_on(PoolHandle::Global, ArenaHandle::Fresh, &arena_request).unwrap()
        });
        println!("   -> {:.1} MB/s", r.mbs(abytes));
        let arena = Arena::new();
        // Warm the free lists, then recycle the output each iteration
        // so the steady state allocates nothing.
        let warm_resp =
            engine::execute_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &arena_request)
                .unwrap();
        arena.adopt(warm_resp.output.data);
        let misses_before = arena.stats().misses;
        let r = bench_fn("arena-reuse mitigate", warm, samp, || {
            let resp =
                engine::execute_on(PoolHandle::Global, ArenaHandle::Pooled(&arena), &arena_request)
                    .unwrap();
            arena.adopt(resp.output.data);
            resp.stats
        });
        let ast = arena.stats();
        println!(
            "   -> {:.1} MB/s ({} hits, {} warm misses, {:.0}% reuse)",
            r.mbs(abytes),
            ast.hits,
            ast.misses - misses_before,
            ast.reuse_fraction() * 100.0
        );
    }

    // Batched serving layer: N independent fields concurrently on the
    // shared pool (through the engine batch path) vs a sequential
    // per-field loop.
    println!("\n== engine batch path ==");
    let batch_n: usize = if quick { 4 } else { 8 };
    let batch_side = 48usize;
    let batch_requests: Vec<MitigationRequest> = (0..batch_n)
        .map(|i| {
            let orig =
                generate(DatasetKind::CombustionLike, &[batch_side; 3], 100 + i as u64);
            let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
            let (q, dq) = quantize_grid(&orig, eb);
            MitigationRequest::new(dq, q, eb)
        })
        .collect();
    let batch_bytes = batch_n * batch_side.pow(3) * 4;
    let batch_engine = Engine::builder().build();
    let r = bench_fn(
        &format!("Engine::run_batch ({batch_n} x {batch_side}^3)"),
        warm,
        samp,
        || {
            // Request clones are Arc pointer bumps, matching the old
            // slice-based wrapper's per-call cost.
            let results = batch_engine.run_batch(batch_requests.clone());
            assert!(results.iter().all(|r| r.is_ok()));
            results
        },
    );
    println!("   -> {:.1} MB/s aggregate", r.mbs(batch_bytes));
    let r = bench_fn(
        &format!("sequential loop ({batch_n} x {batch_side}^3)"),
        warm,
        samp,
        || {
            batch_requests
                .iter()
                .map(|req| engine::execute(req).unwrap())
                .collect::<Vec<_>>()
        },
    );
    println!("   -> {:.1} MB/s aggregate", r.mbs(batch_bytes));

    // Streaming admission: the same fields submitted one by one through
    // the bounded queue (every 4th interactive), waited on tickets —
    // the per-job queue overhead vs the batch path is the delta. A
    // fresh engine, so the stats below describe only this section; two
    // shards exercise the router on every submission.
    println!("\n== streaming admission (sharded engine, queue + tickets) ==");
    let stream_engine = Engine::builder().shards(2).shared_arena(true).build();
    let r = bench_fn(
        &format!("submit+wait stream ({batch_n} x {batch_side}^3, 2 shards)"),
        warm,
        samp,
        || {
            let tickets: Vec<_> = batch_requests
                .iter()
                .enumerate()
                .map(|(i, req)| {
                    let mut req = req.clone().tenant(format!("bench-t{}", i % 3));
                    if i % 4 == 0 {
                        req = req.interactive();
                    }
                    stream_engine.submit(req).expect("admission")
                })
                .collect();
            let responses: Vec<_> = tickets.into_iter().map(|t| t.wait()).collect();
            assert!(responses.iter().all(|r| r.is_ok()));
            responses
        },
    );
    println!("   -> {:.1} MB/s aggregate", r.mbs(batch_bytes));
    let st = stream_engine.stats().aggregate();
    println!(
        "   -> stats: submitted {} (interactive {} / bulk {}), max shard queue depth {}, mean queue wait {:.2} ms",
        st.submitted,
        st.interactive_done,
        st.bulk_done,
        st.max_queue_depth,
        st.total_queue_wait_s * 1e3 / st.submitted.max(1) as f64
    );

    // Tiled streaming executor vs the whole-field path on the largest
    // bench grid, fused with the seeking SZp decoder: the acceptance
    // numbers are (a) first-tile latency well under the whole-field
    // wall (the streaming-consumer win), and (b) arena peak scratch
    // under the published tile budget (the O(tile × lanes) memory
    // claim, counter-proven rather than asserted in prose).
    println!("\n== tiled streaming executor ({side}^3 SZp stream, threads = 4) ==");
    let tside = side / 4;
    let tiled_cfg = TiledConfig::new(&[tside; 3]);
    let t_cfg = MitigationConfig { threads: 4, ..Default::default() };
    let r_whole = bench_fn("whole-field decode+mitigate", warm, samp, || {
        let dec = SzpLike::default().decompress(&stream_s).unwrap();
        let req =
            MitigationRequest::new(dec.grid, dec.quant_indices, dec.bound).config(t_cfg);
        engine::execute(&req).unwrap()
    });
    println!("   -> {:.1} MB/s", r_whole.mbs(bytes));
    let t_codec = SzpLike::default();
    let t_arena = Arena::new();
    let mut first_tile_min = f64::INFINITY;
    let r_tiled = bench_fn(
        &format!("tiled decode+mitigate (tile {tside}^3)"),
        warm,
        samp,
        || {
            let outcome = run_tiled_szp(
                PoolHandle::Global,
                ArenaHandle::Pooled(&t_arena),
                &t_codec,
                &stream_s,
                &t_cfg,
                &tiled_cfg,
                &|_| {},
            )
            .unwrap();
            first_tile_min = first_tile_min.min(outcome.first_tile.as_secs_f64());
            outcome
        },
    );
    println!("   -> {:.1} MB/s", r_tiled.mbs(bytes));
    let t_shape = qai::data::grid::Shape::new(&dims);
    let t_budget = tiled_cfg.scratch_budget_bytes(&t_shape, 4);
    let t_peak = t_arena.stats().bytes_peak;
    let first_frac = first_tile_min / r_whole.mean.max(1e-12);
    println!(
        "   -> first tile in {:.2} ms = {:.2}x whole-field ({:.1} ms); target < 0.25x",
        first_tile_min * 1e3,
        first_frac,
        r_whole.mean * 1e3
    );
    println!(
        "   -> peak scratch {} B of {} B budget ({:.1}% used, whole-field working set ~{} B)",
        t_peak,
        t_budget,
        t_peak as f64 / t_budget as f64 * 100.0,
        n * qai::mitigation::SCRATCH_BYTES_PER_ELEM
    );

    let record = format!(
        "{{\n  \"bench\": \"tiled\",\n  \"generator\": \"cargo bench --bench hotpath_microbench{}\",\n  \
         \"grid\": {side},\n  \"tile\": {tside},\n  \"threads\": 4,\n  \
         \"whole_field_s\": {:.6},\n  \"tiled_total_s\": {:.6},\n  \
         \"first_tile_s\": {:.6},\n  \"first_tile_frac\": {:.6},\n  \
         \"scratch_peak_bytes\": {t_peak},\n  \"scratch_budget_bytes\": {t_budget}\n}}",
        if quick { " -- --quick" } else { "" },
        r_whole.mean,
        r_tiled.mean,
        first_tile_min,
        first_frac,
    );
    qai::bench_support::append_json_record("BENCH_tiled.json", &record);

    bench_simd(quick);

    println!("\nhotpath_microbench: OK");
}

/// Scalar-vs-vector columns for the `util::simd` hot kernels: each
/// kernel runs through its `*_with` entry point forced to
/// `SimdLevel::Scalar` and again at the active dispatch level, and the
/// per-kernel time pairs plus speedups append to the BENCH_simd.json
/// trajectory. On a machine whose best level *is* scalar the columns
/// coincide and every speedup reads ~1.0 — the record still documents
/// that run's level. The Huffman row compares the bit-serial reference
/// decoder against the flat-table fast path through
/// `decode_into_with`, the same parity hook the tests pin.
fn bench_simd(quick: bool) {
    use qai::compressors::{bitio, huffman, lorenzo};
    use qai::util::simd::{self, SimdLevel};

    let level = simd::level();
    let side = if quick { 64 } else { 128 };
    let (warm, samp) = if quick { (1, 3) } else { (2, 5) };
    let dims = [side, side, side];
    let n = side * side * side;

    println!("\n== simd kernels: scalar vs {} ==", level.token());

    let orig = generate(DatasetKind::MirandaLike, &dims, 7);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let inv_q = 1.0 / (2.0 * eb.abs);
    let two_eps = 2.0 * eb.abs;
    let (q, dq) = quantize_grid(&orig, eb);

    let mut rows: Vec<(&'static str, f64, f64)> = Vec::new();

    let mut qout = vec![0i64; n];
    let s = bench_fn("quantize [scalar]", warm, samp, || {
        simd::quantize_with(SimdLevel::Scalar, &orig.data, inv_q, &mut qout)
    });
    let v = bench_fn(&format!("quantize [{}]", level.token()), warm, samp, || {
        simd::quantize_with(level, &orig.data, inv_q, &mut qout)
    });
    rows.push(("quantize", s.mean, v.mean));

    let mut fout = vec![0f32; n];
    let s = bench_fn("dequantize [scalar]", warm, samp, || {
        simd::dequantize_into_with(SimdLevel::Scalar, &q.data, two_eps, &mut fout)
    });
    let v = bench_fn(&format!("dequantize [{}]", level.token()), warm, samp, || {
        simd::dequantize_into_with(level, &q.data, two_eps, &mut fout)
    });
    rows.push(("dequantize", s.mean, v.mean));

    let residuals = lorenzo::forward_with(SimdLevel::Scalar, &q);
    let s = bench_fn("lorenzo forward [scalar]", warm, samp, || {
        lorenzo::forward_with(SimdLevel::Scalar, &q)
    });
    let v = bench_fn(&format!("lorenzo forward [{}]", level.token()), warm, samp, || {
        lorenzo::forward_with(level, &q)
    });
    rows.push(("lorenzo_forward", s.mean, v.mean));

    let s = bench_fn("lorenzo inverse [scalar]", warm, samp, || {
        lorenzo::inverse_with(SimdLevel::Scalar, &residuals, q.shape)
    });
    let v = bench_fn(&format!("lorenzo inverse [{}]", level.token()), warm, samp, || {
        lorenzo::inverse_with(level, &residuals, q.shape)
    });
    rows.push(("lorenzo_inverse", s.mean, v.mean));

    // Synthetic distance/sign fields with the real sentinel mix (zero
    // and INF lanes) so the vector path's sentinel blends are exercised.
    let inf = qai::mitigation::edt::INF;
    let d1: Vec<i64> =
        (0..n).map(|i| if i % 97 == 0 { inf } else { (i % 61) as i64 + 1 }).collect();
    let d2: Vec<i64> = (0..n).map(|i| if i % 89 == 0 { 0 } else { (i % 53) as i64 + 1 }).collect();
    let sgn: Vec<i8> = (0..n)
        .map(|i| match i % 5 {
            0 => 0i8,
            1 | 2 => 1,
            _ => -1,
        })
        .collect();
    let eta_eps = 0.9 * eb.abs;
    let mut work = dq.data.clone();
    let s = bench_fn("compensate [scalar]", warm, samp, || {
        work.copy_from_slice(&dq.data);
        simd::compensate_with(SimdLevel::Scalar, &mut work, &d1, &d2, &sgn, eta_eps, inf)
    });
    let v = bench_fn(&format!("compensate [{}]", level.token()), warm, samp, || {
        work.copy_from_slice(&dq.data);
        simd::compensate_with(level, &mut work, &d1, &d2, &sgn, eta_eps, inf)
    });
    rows.push(("compensate", s.mean, v.mean));

    let kernel: Vec<f64> = {
        let mut k: Vec<f64> =
            (0..9).map(|t| (-((t as f64 - 4.0).powi(2)) / 8.0).exp()).collect();
        let sum: f64 = k.iter().sum();
        k.iter_mut().for_each(|x| *x /= sum);
        k
    };
    let line: Vec<f64> = (0..n + kernel.len() - 1).map(|i| (i as f64 * 0.37).sin()).collect();
    let mut cout = vec![0f64; n];
    let s = bench_fn("convolve [scalar]", warm, samp, || {
        simd::convolve_valid_with(SimdLevel::Scalar, &mut cout, &line, &kernel)
    });
    let v = bench_fn(&format!("convolve [{}]", level.token()), warm, samp, || {
        simd::convolve_valid_with(level, &mut cout, &line, &kernel)
    });
    rows.push(("convolve", s.mean, v.mean));

    let lof = 0.5f64;
    let sinv = 1.0 / 255.0f64;
    let mut mx = vec![0f64; n];
    let mut my = vec![0f64; n];
    let mut mxx = vec![0f64; n];
    let mut myy = vec![0f64; n];
    let mut mxy = vec![0f64; n];
    let s = bench_fn("ssim moments [scalar]", warm, samp, || {
        simd::ssim_moments_with(
            SimdLevel::Scalar,
            &orig.data,
            &dq.data,
            lof,
            sinv,
            &mut mx,
            &mut my,
            &mut mxx,
            &mut myy,
            &mut mxy,
        )
    });
    let v = bench_fn(&format!("ssim moments [{}]", level.token()), warm, samp, || {
        simd::ssim_moments_with(
            level,
            &orig.data,
            &dq.data,
            lof,
            sinv,
            &mut mx,
            &mut my,
            &mut mxx,
            &mut myy,
            &mut mxy,
        )
    });
    rows.push(("ssim_moments", s.mean, v.mean));

    let symbols: Vec<u32> =
        residuals.iter().map(|&r| (bitio::zigzag(r).min(4095)) as u32).collect();
    let buf = huffman::encode(&symbols);
    let mut dout = vec![0u32; symbols.len()];
    let s = bench_fn("huffman decode [bit-serial]", warm, samp, || {
        huffman::decode_into_with(&buf, &mut dout, false).unwrap()
    });
    let v = bench_fn("huffman decode [table]", warm, samp, || {
        huffman::decode_into_with(&buf, &mut dout, true).unwrap()
    });
    rows.push(("huffman_decode", s.mean, v.mean));

    println!("   kernel            scalar_ms  simd_ms  speedup  (simd = {})", level.token());
    for &(name, sm, vm) in &rows {
        println!(
            "   {:<17} {:>9.3} {:>8.3} {:>7.2}x",
            name,
            sm * 1e3,
            vm * 1e3,
            sm / vm.max(1e-12)
        );
    }

    let mut fields = String::new();
    for &(name, sm, vm) in &rows {
        fields.push_str(&format!(
            ",\n  \"{name}_scalar_s\": {:.9},\n  \"{name}_simd_s\": {:.9},\n  \"{name}_speedup\": {:.3}",
            sm,
            vm,
            sm / vm.max(1e-12)
        ));
    }
    let record = format!(
        "{{\n  \"bench\": \"simd\",\n  \"generator\": \"cargo bench --bench hotpath_microbench{}\",\n  \
         \"level\": \"{}\",\n  \"grid\": {side}{fields}\n}}",
        if quick { " -- --quick" } else { "" },
        level.token(),
    );
    qai::bench_support::append_json_record("BENCH_simd.json", &record);
}
