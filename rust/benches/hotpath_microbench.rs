//! Hot-path microbenchmarks — the §Perf driver (DESIGN.md §9).
//!
//! Times each pipeline stage (A–E), the end-to-end pipeline, the EDT in
//! isolation, the compressor codecs, and SSIM, on a 128³ block; prints
//! MB/s so before/after optimization deltas are directly comparable
//! (EXPERIMENTS.md §Perf records the iteration log).

use qai::bench_support::harness::bench_fn;
use qai::compressors::{cusz::CuszLike, cuszp::CuszpLike, szp::SzpLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::ssim;
use qai::mitigation::boundary::boundary_and_sign;
use qai::mitigation::edt::edt;
use qai::mitigation::interpolate::compensate;
use qai::mitigation::pipeline::{mitigate_with_stats, MitigationConfig};
use qai::mitigation::sign::propagate_signs;
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let side = if quick { 64 } else { 128 };
    let (warm, samp) = if quick { (1, 3) } else { (2, 5) };
    let dims = [side, side, side];
    let n = side * side * side;
    let bytes = n * 4;

    let orig = generate(DatasetKind::MirandaLike, &dims, 1);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);

    println!("== stage timings on {side}^3 ({:.1} MB) ==", bytes as f64 / 1e6);
    let r = bench_fn("A: boundary_and_sign", warm, samp, || boundary_and_sign(&q, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let bres = boundary_and_sign(&q, 1);
    let r = bench_fn("B: EDT (with features)", warm, samp, || edt(&bres.mask, true, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let e1 = edt(&bres.mask, true, 1);
    let nearest = e1.nearest.as_ref().unwrap();
    let r = bench_fn("C: propagate_signs + B2", warm, samp, || {
        propagate_signs(&bres.mask, &bres.sign, nearest, 1)
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let (s, b2) = propagate_signs(&bres.mask, &bres.sign, nearest, 1);
    let r = bench_fn("D: EDT (no features)", warm, samp, || edt(&b2, false, 1));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let e2 = edt(&b2, false, 1);
    let r = bench_fn("E: compensate", warm, samp, || {
        let mut data = dq.data.clone();
        compensate(&mut data, &e1.dist_sq, &e2.dist_sq, &s.data, 0.9 * eb.abs, 1);
        data
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    let r = bench_fn("pipeline end-to-end", warm, samp, || {
        mitigate_with_stats(&dq, &q, eb, &MitigationConfig::default()).unwrap()
    });
    println!("   -> {:.1} MB/s (paper §Perf target: >= ~10 MB/s/rank class)", r.mbs(bytes));

    println!("\n== substrate timings ==");
    let r = bench_fn("cuSZ-like compress", warm, samp, || CuszLike.compress(&orig, eb).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream = CuszLike.compress(&orig, eb).unwrap();
    let r = bench_fn("cuSZ-like decompress", warm, samp, || CuszLike.decompress(&stream).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let r = bench_fn("cuSZp2-like compress", warm, samp, || CuszpLike.compress(&orig, eb).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream_p = CuszpLike.compress(&orig, eb).unwrap();
    let r =
        bench_fn("cuSZp2-like decompress", warm, samp, || CuszpLike.decompress(&stream_p).unwrap());
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let stream_s = SzpLike::default().compress(&orig, eb).unwrap();
    let r = bench_fn("SZp-like decompress", warm, samp, || {
        SzpLike::default().decompress(&stream_s).unwrap()
    });
    println!("   -> {:.1} MB/s", r.mbs(bytes));
    let dec = CuszLike.decompress(&stream).unwrap();
    let r = bench_fn("SSIM (w=7, s=2)", warm, samp, || ssim(&orig, &dec.grid, 7, 2));
    println!("   -> {:.1} MB/s", r.mbs(bytes));

    println!("\nhotpath_microbench: OK");
}
