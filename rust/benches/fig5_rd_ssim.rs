//! Fig. 5 — rate-distortion with SSIM: EB→SSIM and bit-rate→SSIM curves
//! for cuSZ-like and cuSZp2-like on the four small-scale dataset
//! analogs, comparing quantized / Gaussian / uniform / Wiener / ours.
//!
//! Shape checks (paper §VIII-D): ours never degrades SSIM meaningfully,
//! improves most at moderate-to-large ε, and the largest gains appear on
//! the smooth-plateau (S3D-like) data.

use qai::bench_support::rd::{method_value, sweep};
use qai::bench_support::tables::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let points = sweep(quick);

    let mut table = Table::new(&[
        "codec", "dataset", "rel_eb", "bits/val", "SSIM_q", "SSIM_gauss", "SSIM_unif",
        "SSIM_wien", "SSIM_ours", "gain%",
    ]);
    let mut max_gain = f64::NEG_INFINITY;
    let mut max_gain_at = (String::new(), 0.0);
    let mut degradations = 0usize;
    for p in &points {
        let q = method_value(p, "quantized", true);
        let ours = method_value(p, "ours", true);
        let gain = (ours - q) / q.abs().max(1e-12) * 100.0;
        if gain > max_gain {
            max_gain = gain;
            max_gain_at = (format!("{}/{}", p.codec, p.dataset), p.rel_eb);
        }
        if gain < -0.5 {
            degradations += 1;
        }
        table.row(&[
            p.codec.into(),
            p.dataset.into(),
            format!("{:.0e}", p.rel_eb),
            format!("{:.3}", p.bit_rate),
            format!("{q:.4}"),
            format!("{:.4}", method_value(p, "gaussian", true)),
            format!("{:.4}", method_value(p, "uniform", true)),
            format!("{:.4}", method_value(p, "wiener", true)),
            format!("{ours:.4}"),
            format!("{gain:+.2}"),
        ]);
    }
    table.print("Fig. 5: rate-distortion (SSIM)");
    println!(
        "\nlargest SSIM gain: {max_gain:+.2}% at {} ε={:.0e}",
        max_gain_at.0, max_gain_at.1
    );
    assert!(max_gain > 0.3, "expected a visible SSIM gain somewhere in the sweep");
    assert!(
        degradations <= points.len() / 10,
        "ours degraded SSIM in {degradations}/{} cells",
        points.len()
    );

    // ε→SSIM series for one representative panel (S3D-like / cuSZ).
    let series: Vec<(f64, f64)> = points
        .iter()
        .filter(|p| p.codec == "cuSZ" && p.dataset == "S3D")
        .map(|p| (p.rel_eb, method_value(p, "ours", true)))
        .collect();
    qai::bench_support::tables::print_series("S3D/cuSZ: ε vs SSIM (ours)", "rel_eb", "SSIM", &series);
    println!("\nfig5_rd_ssim: OK");
}
