//! η ablation (paper §VI: "η = 0.9 yields the best results most of the
//! time", from an offline sweep the paper omits for space). Sweeps the
//! compensation factor and reports SSIM / PSNR / max-error headroom.

use qai::bench_support::tables::Table;
use qai::compressors::{cusz::CuszLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{max_rel_error, psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::MitigationConfig;
use qai::quant::ErrorBound;
use qai::SharedGrid;

fn main() {
    // The same grid the engine's quality-target search sweeps — keeping
    // the ablation and the online search on one list means this table
    // documents exactly the candidates a served request can pick from.
    let etas = qai::mitigation::quality::ETA_CANDIDATES;
    let cases = [
        (DatasetKind::MirandaLike, [64usize, 64, 64], 1e-2),
        (DatasetKind::CombustionLike, [64, 64, 64], 1e-2),
    ];

    for (kind, dims, rel) in cases {
        let orig = generate(kind, &dims, 9);
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let dec = CuszLike.decompress(&CuszLike.compress(&orig, eb).unwrap()).unwrap();
        // Shared handles: each per-η request clone is a pointer bump.
        let dq: SharedGrid<f32> = dec.grid.into();
        let qg: SharedGrid<i64> = dec.quant_indices.into();

        let mut table = Table::new(&["eta", "SSIM", "PSNR(dB)", "max_rel_err", "<=(1+eta)eps"]);
        let mut best = (0.0f64, f64::NEG_INFINITY);
        for &eta in &etas {
            let cfg = MitigationConfig { eta, ..Default::default() };
            let request = MitigationRequest::new(dq.clone(), qg.clone(), eb).config(cfg);
            let out = engine::execute(&request).unwrap().output;
            let s = ssim(&orig, &out, 7, 2);
            let p = psnr(&orig.data, &out.data);
            let e = max_rel_error(&orig.data, &out.data);
            let ok = e <= (1.0 + eta) * rel * (1.0 + 1e-5);
            assert!(ok, "eta={eta}: bound violated");
            if p > best.1 {
                best = (eta, p);
            }
            table.row(&[
                format!("{eta:.1}"),
                format!("{s:.4}"),
                format!("{p:.2}"),
                format!("{e:.5}"),
                format!("{ok}"),
            ]);
        }
        table.print(&format!("η ablation on {} (ε = {rel:.0e})", kind.paper_name()));
        println!("best PSNR at η = {:.1}", best.0);
        assert!(best.0 >= 0.7, "compensation should clearly beat η=0 (no compensation)");
    }
    println!("\nablation_eta: OK");
}
