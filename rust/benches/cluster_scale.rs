//! Cluster-scale serving harness: the same request set pushed through a
//! single-process engine and through a 2-process cluster (this process
//! joins a forked `qai serve --listen` node over localhost TCP, and
//! rendezvous routing splits the tenants across both).
//!
//! The point is not that two processes on one host go faster — framing
//! grids over a socket costs more than an in-process Arc bump, and the
//! numbers say so honestly. The point is the **scaling contract**: the
//! cluster path must produce bit-identical outputs while measurably
//! moving part of the stream over the wire, and the measured 1- vs
//! 2-process throughput plus transport byte counters land in
//! `BENCH_cluster.json` (a JSON array of per-run records, like
//! `BENCH_serve.json`) so CI can sanity-check the trajectory.

use qai::cluster::node::{request_shutdown, ClusterEngine};
use qai::cluster::registry::NodeRegistry;
use qai::data::grid::Grid;
use qai::data::synthetic::{generate, DatasetKind};
use qai::mitigation::engine::{Engine, MitigationRequest, TransportStatsSource};
use qai::quant::{quantize_grid, ErrorBound, QIndex, ResolvedBound};
use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Instant;

const DIMS: &[usize] = &[24, 24, 24];
const TENANTS: usize = 8;

struct ChildGuard(Child);

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _killed = self.0.kill();
        let _reaped = self.0.wait();
    }
}

fn make_input(seed: u64) -> (Grid<f32>, Grid<QIndex>, ResolvedBound) {
    let orig = generate(DatasetKind::MirandaLike, DIMS, seed);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);
    (dq, q, eb)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let jobs_n: usize = if quick { 16 } else { 64 };

    let inputs: Vec<(Grid<f32>, Grid<QIndex>, ResolvedBound)> =
        (0..8).map(|i| make_input(900 + i)).collect();

    // Pick the tenant set so rendezvous routing provably splits it:
    // half the names route to the local node (101), half to the forked
    // listener (202).
    let mut reg = NodeRegistry::new(101);
    reg.add(202);
    let mut locals = Vec::new();
    let mut remotes = Vec::new();
    for i in 0..256 {
        let t = format!("t{i}");
        if reg.route(&t) == Some(101) {
            locals.push(t);
        } else {
            remotes.push(t);
        }
    }
    assert!(
        locals.len() >= TENANTS / 2 && remotes.len() >= TENANTS / 2,
        "pathological rendezvous split over 256 candidate tenants"
    );
    let tenants: Vec<String> = locals
        .iter()
        .take(TENANTS / 2)
        .cloned()
        .chain(remotes.iter().take(TENANTS / 2).cloned())
        .collect();
    let tenant_of = |i: usize| tenants[i % tenants.len()].clone();

    // ---- 1 process: plain sharded engine. ----------------------------
    let single = Engine::builder().shards(2).build();
    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(jobs_n);
    for i in 0..jobs_n {
        let (dq, q, eb) = &inputs[i % inputs.len()];
        let req = MitigationRequest::new(dq.clone(), q.clone(), *eb).tenant(tenant_of(i));
        tickets.push(single.submit(req).expect("single-process submit"));
    }
    let mut single_outputs = Vec::with_capacity(jobs_n);
    for ticket in tickets {
        single_outputs.push(ticket.wait().expect("single-process job").output);
    }
    let single_wall = t0.elapsed().as_secs_f64();
    let single_thr = jobs_n as f64 / single_wall.max(1e-12);

    // ---- 2 processes: forked listener + this process as joiner. ------
    let child = Command::new(env!("CARGO_BIN_EXE_qai"))
        .args(["serve", "--listen", "127.0.0.1:0", "--node-id", "202", "--shards", "2"])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .expect("spawn listener process");
    let mut guard = ChildGuard(child);
    let mut line = String::new();
    BufReader::new(guard.0.stdout.take().expect("child stdout"))
        .read_line(&mut line)
        .expect("read listen line");
    let addr = line
        .trim()
        .split(" listening on ")
        .nth(1)
        .unwrap_or_else(|| panic!("unexpected listen line: {line:?}"))
        .to_string();

    let local_engine = Arc::new(Engine::builder().shards(2).build());
    let cluster = ClusterEngine::new(101, Arc::clone(&local_engine));
    let peer = cluster.join(&addr).expect("join listener");
    assert_eq!(peer, 202);

    let t0 = Instant::now();
    let mut tickets = Vec::with_capacity(jobs_n);
    let mut remote_jobs = 0usize;
    for i in 0..jobs_n {
        let (dq, q, eb) = &inputs[i % inputs.len()];
        let req = MitigationRequest::new(dq.clone(), q.clone(), *eb).tenant(tenant_of(i));
        let ticket = cluster.submit(req).expect("cluster submit");
        if ticket.is_remote() {
            remote_jobs += 1;
        }
        tickets.push(ticket);
    }
    let mut cluster_outputs = Vec::with_capacity(jobs_n);
    for ticket in tickets {
        cluster_outputs.push(ticket.wait().expect("cluster job").output);
    }
    let cluster_wall = t0.elapsed().as_secs_f64();
    let cluster_thr = jobs_n as f64 / cluster_wall.max(1e-12);
    let local_jobs = jobs_n - remote_jobs;

    let counters = cluster.transport_stats().transport_counters();
    let sent_bytes: u64 = counters.iter().map(|c| c.sent_bytes).sum();
    let recv_bytes: u64 = counters.iter().map(|c| c.recv_bytes).sum();
    let sent_msgs: u64 = counters.iter().map(|c| c.sent_msgs).sum();

    request_shutdown(&addr, 101).expect("shutdown listener");
    let status = guard.0.wait().expect("reap listener");

    // ---- Sanity: the whole point of the contract. --------------------
    assert!(status.success(), "listener exited with {status:?}");
    assert!(remote_jobs > 0, "no job crossed the wire — routing is broken");
    assert!(local_jobs > 0, "no job stayed local — routing is broken");
    assert!(sent_bytes > 0 && recv_bytes > 0, "transport counters must see the traffic");
    for (i, (got, want)) in cluster_outputs.iter().zip(&single_outputs).enumerate() {
        assert_eq!(
            got.data, want.data,
            "job {i}: cluster output differs from single-process output"
        );
    }

    println!("cluster_scale: {jobs_n} jobs of {DIMS:?}, {TENANTS} tenants");
    println!("  1 process : {single_thr:.1} jobs/s ({single_wall:.3}s wall)");
    println!(
        "  2 process : {cluster_thr:.1} jobs/s ({cluster_wall:.3}s wall), \
         {local_jobs} local / {remote_jobs} remote"
    );
    println!(
        "  wire      : {sent_bytes} B sent / {recv_bytes} B recv in {sent_msgs} msgs to peer {peer}"
    );
    println!("  outputs   : bit-identical across both runs");

    let record = format!(
        "{{\n  \"bench\": \"cluster_scale\",\n  \"generator\": \"cargo bench --bench cluster_scale{}\",\n  \
         \"jobs\": {},\n  \"single_process_throughput_jobs_per_s\": {:.3},\n  \
         \"single_process_wall_s\": {:.6},\n  \"two_process_throughput_jobs_per_s\": {:.3},\n  \
         \"two_process_wall_s\": {:.6},\n  \"local_jobs\": {},\n  \"remote_jobs\": {},\n  \
         \"wire_sent_bytes\": {},\n  \"wire_recv_bytes\": {},\n  \"wire_sent_msgs\": {},\n  \
         \"bit_identical\": true\n}}",
        if quick { " -- --quick" } else { "" },
        jobs_n,
        single_thr,
        single_wall,
        cluster_thr,
        cluster_wall,
        local_jobs,
        remote_jobs,
        sent_bytes,
        recv_bytes,
        sent_msgs,
    );
    println!();
    qai::bench_support::append_json_record("BENCH_cluster.json", &record);
    println!("cluster_scale: OK");
}
