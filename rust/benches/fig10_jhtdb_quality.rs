//! Fig. 10 — JHTDB EB-distortion under the Approximate strategy at high
//! rank counts: SSIM and PSNR of the quantized vs compensated data
//! across the error-bound sweep. The paper reports up to +76% SSIM and
//! +14% PSNR at ε = 1e-2.

use qai::bench_support::tables::Table;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::quant::{quantize_grid, ErrorBound};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let dims = if quick { [64usize, 64, 64] } else { [96, 96, 96] };
    let orig = generate(DatasetKind::TurbulenceLike, &dims, 512);
    let bounds: &[f64] = if quick { &[1e-3, 1e-2] } else { &[1e-3, 2e-3, 5e-3, 1e-2, 2e-2] };

    let mut table = Table::new(&[
        "rel_eb", "SSIM_q", "SSIM_ours", "dSSIM%", "PSNR_q", "PSNR_ours", "dPSNR%",
    ]);
    let mut best_ssim_gain = f64::NEG_INFINITY;
    for &rel in bounds {
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let (q, dq) = quantize_grid(&orig, eb);
        let cfg =
            DistributedConfig { ranks: 64, strategy: Strategy::Approximate, ..Default::default() };
        let (out, _) = run_distributed(&dq, &q, eb, &cfg).unwrap();
        let s0 = ssim(&orig, &dq, 7, 2);
        let s1 = ssim(&orig, &out, 7, 2);
        let p0 = psnr(&orig.data, &dq.data);
        let p1 = psnr(&orig.data, &out.data);
        let ds = (s1 - s0) / s0.abs().max(1e-12) * 100.0;
        best_ssim_gain = best_ssim_gain.max(ds);
        table.row(&[
            format!("{rel:.0e}"),
            format!("{s0:.4}"),
            format!("{s1:.4}"),
            format!("{ds:+.2}"),
            format!("{p0:.2}"),
            format!("{p1:.2}"),
            format!("{:+.2}", (p1 - p0) / p0 * 100.0),
        ]);
    }
    table.print("Fig. 10: JHTDB-analog EB-distortion (Approximate, 64 ranks)");
    assert!(best_ssim_gain > 0.2, "expected SSIM gains on the turbulence analog");
    println!("\nbest SSIM gain in sweep: {best_ssim_gain:+.2}%");
    println!("fig10_jhtdb_quality: OK");
}
