#!/usr/bin/env python3
"""Public-API surface snapshot check.

Guards against accidental public-surface growth: every `pub` item of the
`qai` crate is extracted into a sorted, deterministic item list and
diffed against the checked-in snapshot (tools/api_surface.txt). CI runs
`check`; a deliberate surface change regenerates the snapshot with
`update`, which makes the growth reviewable as an ordinary diff.

The extractor is a line-level scan of `rust/src/**/*.rs` (the design
also works by diffing `cargo doc` item lists, but a source scan needs no
toolchain, so the check runs in every environment — including offline
ones). It records items declared `pub` — functions, types, traits,
consts, statics, modules, macros, and re-exports — attributed to the
module derived from the file path. Restricted visibility (`pub(crate)`
and friends), `#[cfg(test)]` modules, and doc examples are excluded.
Impl-block methods are attributed to their file's module; that is
coarser than a full path but stable, and a new public method still shows
up as a new line.

Usage:
  python3 tools/api_surface.py check    # exit 1 + diff on drift
  python3 tools/api_surface.py update   # rewrite tools/api_surface.txt
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "rust" / "src"
SNAPSHOT = REPO / "tools" / "api_surface.txt"

# `pub` followed by an item keyword (not `pub(crate)` etc.) and a name.
ITEM_RE = re.compile(
    r"^\s*pub\s+"
    r"(?:async\s+|unsafe\s+|extern\s+\"[^\"]*\"\s+)*"
    r"(?P<kind>fn|struct|enum|trait|type|const|static|mod|macro_rules!|use)\s+"
    r"(?P<rest>.+)$"
)
NAME_RE = re.compile(r"[A-Za-z_][A-Za-z0-9_]*")


def module_of(path: Path) -> str:
    rel = path.relative_to(SRC).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] in ("mod", "lib"):
        parts = parts[:-1]
    if parts and parts[-1] == "main":
        return "bin::qai"
    return "::".join(["qai"] + parts)


def use_targets(rest: str) -> list:
    """Item names exported by a `pub use` line (handles `{a, b as c}`)."""
    rest = rest.rstrip(";").strip()
    brace = rest.find("{")
    names = []
    if brace >= 0:
        inner = rest[brace + 1 : rest.rfind("}")]
        leaves = [leaf.strip() for leaf in inner.split(",") if leaf.strip()]
    else:
        leaves = [rest]
    for leaf in leaves:
        if " as " in leaf:
            leaf = leaf.split(" as ")[-1].strip()
        else:
            leaf = leaf.split("::")[-1].strip()
        if leaf == "*":
            names.append("*")
        else:
            m = NAME_RE.match(leaf)
            if m:
                names.append(m.group(0))
    return names


def scan_file(path: Path) -> set:
    items = set()
    module = module_of(path)
    in_test_mod = False
    test_depth = 0
    depth = 0
    pending_cfg_test = False
    pending_use = None  # accumulates a rustfmt-wrapped `pub use {...};`
    for raw in path.read_text(encoding="utf-8").splitlines():
        line = raw.split("//")[0]
        stripped = line.strip()
        opens = line.count("{")
        closes = line.count("}")
        if "#[cfg(test)]" in line:
            pending_cfg_test = True
        elif pending_cfg_test and stripped:
            if re.search(r"\bmod\s+\w+", line):
                in_test_mod = True
                test_depth = depth
                pending_cfg_test = False
            elif not stripped.startswith("#["):
                # The cfg(test) gated a non-module item (fn, use, ...):
                # it must not swallow a later, unrelated `mod`.
                pending_cfg_test = False
        depth += opens - closes
        if in_test_mod:
            if depth <= test_depth:
                in_test_mod = False
            continue
        if pending_use is not None:
            pending_use += " " + stripped
            if ";" in stripped:
                for name in use_targets(pending_use):
                    items.add(f"{module}::{name} [reexport]")
                pending_use = None
            continue
        m = ITEM_RE.match(line)
        if not m:
            continue
        kind = m.group("kind")
        rest = m.group("rest")
        if kind == "use":
            if ";" not in rest:
                pending_use = rest
                continue
            for name in use_targets(rest):
                items.add(f"{module}::{name} [reexport]")
            continue
        name_match = NAME_RE.match(rest)
        if not name_match:
            continue
        items.add(f"{module}::{name_match.group(0)} [{kind}]")
    return items


def collect() -> list:
    items = set()
    for path in sorted(SRC.rglob("*.rs")):
        items |= scan_file(path)
    return sorted(items)


def main() -> int:
    mode = sys.argv[1] if len(sys.argv) > 1 else "check"
    current = collect()
    if mode == "update":
        SNAPSHOT.write_text("\n".join(current) + "\n", encoding="utf-8")
        print(f"wrote {len(current)} public items to {SNAPSHOT.relative_to(REPO)}")
        return 0
    if mode != "check":
        print(__doc__)
        return 2
    if not SNAPSHOT.exists():
        print("missing tools/api_surface.txt — run: python3 tools/api_surface.py update")
        return 1
    recorded = [l for l in SNAPSHOT.read_text(encoding="utf-8").splitlines() if l.strip()]
    added = sorted(set(current) - set(recorded))
    removed = sorted(set(recorded) - set(current))
    if not added and not removed:
        print(f"public API surface unchanged ({len(current)} items)")
        return 0
    print("public API surface drifted from tools/api_surface.txt:")
    for line in added:
        print(f"  + {line}")
    for line in removed:
        print(f"  - {line}")
    print(
        "\nif this growth is deliberate, regenerate the snapshot with:\n"
        "  python3 tools/api_surface.py update\n"
        "and commit the diff for review."
    )
    return 1


if __name__ == "__main__":
    sys.exit(main())
