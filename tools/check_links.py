#!/usr/bin/env python3
"""Offline markdown link checker for the docs job.

Scans the given markdown files (and, for directories, every ``*.md``
inside them) for inline links/images ``[text](target)`` and reference
definitions ``[label]: target``, then verifies that every *relative*
target resolves to an existing file or directory, relative to the file
containing the link. Anchors (``#section``) are stripped; external
schemes (``http://``, ``https://``, ``mailto:``) and bare in-page
anchors are skipped — the build environment is offline by design.

Exit status: 0 when every relative link resolves, 1 otherwise (with one
``file: target`` line per broken link on stderr).

Usage: ``python3 tools/check_links.py docs README.md ROADMAP.md``
"""

import re
import sys
from pathlib import Path

INLINE_LINK = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
REF_DEF = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.MULTILINE)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def collect_files(args):
    files = []
    for arg in args:
        path = Path(arg)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.md")))
        elif path.exists():
            files.append(path)
        else:
            print(f"check_links: no such file or directory: {arg}", file=sys.stderr)
            sys.exit(2)
    return files


def targets_in(text):
    # Fenced code blocks routinely contain bracket syntax that is not a
    # link (e.g. Rust attributes); strip them before scanning.
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    for match in INLINE_LINK.finditer(text):
        yield match.group(1)
    for match in REF_DEF.finditer(text):
        yield match.group(1)


def main():
    if len(sys.argv) < 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    broken = []
    checked = 0
    for md in collect_files(sys.argv[1:]):
        text = md.read_text(encoding="utf-8")
        for target in targets_in(text):
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            checked += 1
            if not (md.parent / rel).exists():
                broken.append(f"{md}: {target}")
    for line in broken:
        print(line, file=sys.stderr)
    print(f"check_links: {checked} relative link(s) checked, {len(broken)} broken")
    sys.exit(1 if broken else 0)


if __name__ == "__main__":
    main()
