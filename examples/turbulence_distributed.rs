//! JHTDB-analog distributed run: the three parallelization strategies
//! of §VII-B on a turbulence field, with quality and modeled-scaling
//! reports (the small-scale companion to the Fig. 9/10 benches).
//!
//! Run with: `cargo run --release --example turbulence_distributed`

use qai::bench_support::tables::Table;
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::quant::{quantize_grid, ErrorBound};

fn main() -> anyhow::Result<()> {
    let dims = [96, 96, 96];
    let orig = generate(DatasetKind::TurbulenceLike, &dims, 64);
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let (q, dq) = quantize_grid(&orig, eb);

    let s_dq = ssim(&orig, &dq, 7, 2);
    let p_dq = psnr(&orig.data, &dq.data);
    println!("decompressed (unmitigated): SSIM {s_dq:.4}, PSNR {p_dq:.2} dB\n");

    let mut table = Table::new(&[
        "strategy", "ranks", "SSIM", "PSNR(dB)", "comm(KB)", "modeled_mkspan(ms)", "comm%",
    ]);
    for strategy in [Strategy::Embarrassing, Strategy::Exact, Strategy::Approximate] {
        for ranks in [8usize, 64] {
            let cfg = DistributedConfig { ranks, strategy, ..Default::default() };
            let (out, rep) = run_distributed(&dq, &q, eb, &cfg)?;
            table.row(&[
                strategy.name().to_string(),
                format!("{}", rep.ranks),
                format!("{:.4}", ssim(&orig, &out, 7, 2)),
                format!("{:.2}", psnr(&orig.data, &out.data)),
                format!("{:.1}", rep.total_bytes() as f64 / 1e3),
                format!("{:.2}", rep.modeled_makespan() * 1e3),
                format!("{:.2}", rep.comm_fraction() * 100.0),
            ]);
        }
    }
    table.print("Distributed strategies on JHTDB-analog turbulence (ε=1e-2)");
    println!(
        "\nexpected shape (paper Fig. 4/9): exact = best quality & most comm;\n\
         approximate ≈ exact quality at stencil-only comm; embarrassing = zero comm,\n\
         rank-boundary striping visible as lower SSIM at higher rank counts"
    );
    Ok(())
}
