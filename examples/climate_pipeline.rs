//! End-to-end validation driver (DESIGN.md §4): a multi-field CESM-like
//! 2D climate dataset pushed through the entire stack —
//!
//!   synthetic fields → cuSZ-like compression → decompression →
//!   **distributed** mitigation (approximate strategy, 16 ranks) with
//!   the **PJRT backend** exercising the AOT JAX/Pallas artifacts for
//!   the sequential cross-check — sweeping error bounds and reporting
//!   the paper's headline metrics (SSIM/PSNR before/after, bit-rate,
//!   error-bound compliance).
//!
//! Results of this run are recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with: `cargo run --release --example climate_pipeline`
//! (PJRT cross-check requires `make artifacts`; it degrades to
//! native-only with a notice if artifacts are missing.)

use qai::bench_support::tables::Table;
use qai::compressors::{cusz::CuszLike, Compressor};
use qai::coordinator::{run_distributed, DistributedConfig, Strategy};
use qai::data::synthetic::{field_catalog, DatasetKind};
use qai::metrics::{bit_rate, max_rel_error, psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::{Backend, MitigationConfig};
use qai::quant::ErrorBound;
use qai::SharedGrid;

fn main() -> anyhow::Result<()> {
    let dims = [512, 1024]; // CESM-like aspect (scaled from 1800×3600)
    let fields = field_catalog(DatasetKind::ClimateLike, &dims, 3, 2026);
    let bounds = [1e-3, 5e-3, 1e-2, 2e-2];
    let codec = CuszLike;

    let artifacts_ok = std::path::Path::new(
        &std::env::var("QAI_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )
    .join("manifest.txt")
    .exists();
    if !artifacts_ok {
        eprintln!("note: artifacts missing — skipping the PJRT cross-check lane");
    }

    let mut table = Table::new(&[
        "field", "rel_eb", "bits/val", "SSIM_dq", "SSIM_ours", "dSSIM%", "PSNR_dq", "PSNR_ours",
        "maxrel_ours", "bound_ok",
    ]);
    let mut worst_gain = f64::INFINITY;
    let mut best_gain = f64::NEG_INFINITY;

    for field in &fields {
        for &rel in &bounds {
            let eb = ErrorBound::relative(rel).resolve(&field.grid.data);
            let stream = codec.compress(&field.grid, eb)?;
            let dec = codec.decompress(&stream)?;
            // Shared handles: requests and metrics reuse the same
            // allocations without copying field data.
            let dq: SharedGrid<f32> = dec.grid.into();
            let qg: SharedGrid<i64> = dec.quant_indices.into();

            // Distributed mitigation: 16 ranks, approximate strategy.
            let cfg = DistributedConfig {
                ranks: 16,
                strategy: Strategy::Approximate,
                ..Default::default()
            };
            let (fixed, _rep) = run_distributed(&dq, &qg, eb, &cfg)?;

            // PJRT lane: sequential pipeline through the AOT artifacts,
            // cross-checked against the native path.
            if artifacts_ok && rel == 1e-2 {
                let pjrt_cfg = MitigationConfig { backend: Backend::Pjrt, ..Default::default() };
                let base = MitigationRequest::new(dq.clone(), qg.clone(), eb);
                let out_pjrt = engine::execute(&base.clone().config(pjrt_cfg))?.output;
                let out_native = engine::execute(&base)?.output;
                let dev = qai::metrics::max_abs_error(&out_pjrt.data, &out_native.data);
                anyhow::ensure!(dev < 1e-6, "PJRT/native divergence {dev}");
            }

            let s0 = ssim(&field.grid, &dq, 7, 2);
            let s1 = ssim(&field.grid, &fixed, 7, 2);
            let p0 = psnr(&field.grid.data, &dq.data);
            let p1 = psnr(&field.grid.data, &fixed.data);
            let mr = max_rel_error(&field.grid.data, &fixed.data);
            let gain = (s1 - s0) / s0.abs().max(1e-12) * 100.0;
            worst_gain = worst_gain.min(gain);
            best_gain = best_gain.max(gain);
            let bound_ok = mr <= 1.9 * rel * (1.0 + 1e-5);
            table.row(&[
                field.name.clone(),
                format!("{rel:.0e}"),
                format!("{:.3}", bit_rate(stream.len(), field.grid.len())),
                format!("{s0:.4}"),
                format!("{s1:.4}"),
                format!("{gain:+.2}"),
                format!("{p0:.2}"),
                format!("{p1:.2}"),
                format!("{mr:.5}"),
                format!("{bound_ok}"),
            ]);
            anyhow::ensure!(bound_ok, "relaxed bound violated");
        }
    }

    table.print("End-to-end climate pipeline (cuSZ-like + distributed QAI mitigation)");
    println!("\nheadline: SSIM gain range {worst_gain:+.2}% .. {best_gain:+.2}% across fields/bounds");
    println!("all runs respected the relaxed bound (1+η)ε with η=0.9");
    if artifacts_ok {
        println!("PJRT (AOT JAX/Pallas) lane cross-checked against native: OK");
    }
    Ok(())
}
