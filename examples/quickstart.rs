//! Quickstart: the smallest complete round trip through the stack.
//!
//! Generates a Miranda-like density field, compresses it with the
//! cuSZ-like pipeline at a moderate relative error bound, decompresses,
//! mitigates the pre-quantization banding with quantization-aware
//! interpolation, and prints the quality metrics before/after.
//!
//! Run with: `cargo run --release --example quickstart`

use qai::compressors::{cusz::CuszLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{bit_rate, max_rel_error, psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::mitigation::MitigationConfig;
use qai::quant::ErrorBound;
use qai::SharedGrid;

fn main() -> anyhow::Result<()> {
    // 1. A real-ish small workload: 64³ density field (Fig. 2's analog).
    let orig = generate(DatasetKind::MirandaLike, &[64, 64, 64], 42);

    // 2. Compress with a value-range-relative bound of 1e-2 (the paper's
    //    "moderate error bound" sweet spot — Fig. 7 point B).
    let eb = ErrorBound::relative(1e-2).resolve(&orig.data);
    let codec = CuszLike;
    let stream = codec.compress(&orig, eb)?;
    println!(
        "compressed {} values -> {} bytes ({:.2}x, {:.3} bits/value)",
        orig.len(),
        stream.len(),
        (orig.len() * 4) as f64 / stream.len() as f64,
        bit_rate(stream.len(), orig.len()),
    );

    // 3. Decompress: the reconstruction carries posterization artifacts.
    let dec = codec.decompress(&stream)?;

    // 4. Mitigate (Alg. 4) through the engine front door: boundary
    //    detection -> EDT -> sign propagation -> EDT -> IDW
    //    compensation. The shared handle keeps the decompressed field
    //    alive for the before/after metrics without copying it.
    let cfg = MitigationConfig::default(); // η = 0.9, native backend
    let dq: SharedGrid<f32> = dec.grid.into();
    let request = MitigationRequest::new(dq.clone(), dec.quant_indices, dec.bound)
        .config(cfg)
        .with_stats(true);
    let resp = engine::execute(&request)?;
    let (fixed, stats) = (resp.output, resp.stats.expect("stats requested"));

    // 5. Quality report.
    println!(
        "SSIM  {:.4} -> {:.4}",
        ssim(&orig, &dq, 7, 2),
        ssim(&orig, &fixed, 7, 2)
    );
    println!(
        "PSNR  {:.2} dB -> {:.2} dB",
        psnr(&orig.data, &dq.data),
        psnr(&orig.data, &fixed.data)
    );
    println!(
        "max relative error {:.5} -> {:.5} (relaxed bound {:.5})",
        max_rel_error(&orig.data, &dq.data),
        max_rel_error(&orig.data, &fixed.data),
        (1.0 + cfg.eta) * eb.rel.unwrap()
    );
    println!(
        "mitigation ran at {:.1} MB/s (|B1|={}, |B2|={})",
        stats.throughput_mbs(orig.len()),
        stats.n_boundary1,
        stats.n_boundary2
    );
    Ok(())
}
