//! Fig. 7 case study: the Hurricane-Wf48 analog at three error bounds —
//! point A (low EB, artifacts negligible), point B (moderate EB, the
//! sweet spot), point C (very high EB, information mostly gone) — with
//! a 1D line cut printed for visual inspection of the banding and its
//! repair (the paper's Fig. 2(c)/Fig. 7 views).
//!
//! Run with: `cargo run --release --example case_study`

use qai::bench_support::tables::Table;
use qai::compressors::{cusz::CuszLike, Compressor};
use qai::data::synthetic::{generate, DatasetKind};
use qai::metrics::{psnr, ssim};
use qai::mitigation::engine::{self, MitigationRequest};
use qai::quant::ErrorBound;
use qai::SharedGrid;

fn main() -> anyhow::Result<()> {
    let orig = generate(DatasetKind::HurricaneLike, &[64, 128, 128], 48);
    let codec = CuszLike;
    let points = [("A (low)", 1e-3), ("B (moderate)", 1e-2), ("C (very high)", 8e-2)];

    let mut table =
        Table::new(&["point", "rel_eb", "SSIM_dq", "SSIM_ours", "PSNR_dq", "PSNR_ours"]);
    for (label, rel) in points {
        let eb = ErrorBound::relative(rel).resolve(&orig.data);
        let dec = codec.decompress(&codec.compress(&orig, eb)?)?;
        let dq: SharedGrid<f32> = dec.grid.into();
        let request = MitigationRequest::new(dq.clone(), dec.quant_indices, eb);
        let fixed = engine::execute(&request)?.output;
        table.row(&[
            label.to_string(),
            format!("{rel:.0e}"),
            format!("{:.4}", ssim(&orig, &dq, 7, 2)),
            format!("{:.4}", ssim(&orig, &fixed, 7, 2)),
            format!("{:.2}", psnr(&orig.data, &dq.data)),
            format!("{:.2}", psnr(&orig.data, &fixed.data)),
        ]);

        if rel == 1e-2 {
            // Line cut through the vortex (Fig. 2(c) style view).
            println!("\n1D line cut at point B (i=32, j=64, k=40..72):");
            println!("{:>4} {:>10} {:>10} {:>10}", "k", "orig", "decomp", "ours");
            for k in (40..72).step_by(2) {
                println!(
                    "{:>4} {:>10.4} {:>10.4} {:>10.4}",
                    k,
                    orig.at(32, 64, k),
                    dq.at(32, 64, k),
                    fixed.at(32, 64, k)
                );
            }
        }
    }
    table.print("Fig. 7 analog: Hurricane case study across error-bound regimes");
    println!(
        "\nexpected shape: negligible change at A, large SSIM/PSNR gain at B,\n\
         SSIM-only gain at C (paper: 'works best at moderate error bounds')"
    );
    Ok(())
}
